"""FlexServe REST server — a lean thread-per-connection HTTP front-end.

The paper wraps its ensemble in Flask behind a Gunicorn WSGI server; Flask
is not available in this offline container, so the same architecture is
built on ``socketserver``: a threaded front-end accepts concurrent client
connections (the Gunicorn-worker analogue for IO), with a hand-rolled
keep-alive HTTP/1.1 handler whose per-request cost is a fraction of
``http.server``'s.

Accelerator work is NOT serialized per request.  Ensemble routes
(/v1/infer, /v1/detect) funnel through a ``BatchCoalescer`` that merges
concurrent requests' rows into one bucketed forward; /v1/generate goes
through a ``SchedulerService`` that admits prompts into continuous-batching
decode slots.  ``coalesce=False`` restores the legacy one-request-per-
forward behavior behind a global device lock (kept as the benchmark
baseline).

With a ``ModelManager`` attached, the endpoint gains a lifecycle admin
surface (GET /v1/models/{name}, POST .../load /unload /rollback) and
per-request version-alias targeting on the inference routes — hot swaps
happen under live traffic with zero dropped requests.

Endpoints are defined in repro.serving.api.
"""

from __future__ import annotations

import socketserver
import threading
import time
import urllib.parse
from typing import Any, Dict, Optional

import numpy as np

from repro.core.batching import BucketSpec
from repro.core.engine import InferenceEngine
from repro.core.ensemble import Ensemble
from repro.core.registry import ModelRegistry
from repro.serving import api
from repro.serving.admission import (AdmissionController, DeadlineError,
                                     RequestContext, ShedError)
from repro.serving.coalesce import BatchCoalescer
from repro.serving.generate import GenerationError, GenerationService
from repro.serving.lifecycle import LifecycleError, ModelManager
from repro.serving.modelstore import StoreError


class FlexServeApp:
    """Bundles a registry, an optional ensemble/manager, and an engine.

    ``max_wait_ms`` / ``max_coalesce_rows`` tune the coalescer (how long
    the dispatcher lingers for more rows — ``None`` derives the linger
    adaptively from the observed arrival rate — and the rows-per-forward
    cap); ``num_slots`` sizes each continuous-batching decode pool.  Pass
    a ``manager`` instead of a static ``ensemble`` to serve store-backed,
    hot-swappable models; with a manager attached, generation engines are
    versioned and hot-swappable too (POST /v1/engines/{name}/load).
    """

    def __init__(self, registry: Optional[ModelRegistry] = None,
                 ensemble: Optional[Ensemble] = None,
                 engine: Optional[InferenceEngine] = None, *,
                 manager: Optional[ModelManager] = None,
                 coalesce: bool = True,
                 max_wait_ms: Optional[float] = None,
                 max_coalesce_rows: Optional[int] = None,
                 num_slots: int = 4,
                 max_queue: int = 64,
                 bulk_fraction: float = 0.5,
                 default_deadline_ms: Optional[float] = None,
                 max_stream_buffer: int = 32,
                 generate_token_budget: Optional[int] = None):
        if manager is not None and ensemble is not None:
            raise ValueError("pass either a static ensemble or a manager")
        self.manager = manager
        self.registry = (manager.registry if manager is not None
                         else registry or ModelRegistry())
        self._ensemble = ensemble
        self.engine = engine
        self.device_lock = threading.Lock()
        self.request_count = 0
        self._t0 = time.time()
        self._closing = False
        self._route_stats: Dict[str, Dict[str, float]] = {}
        self._stats_lock = threading.Lock()
        # the generate plane is budgeted in TOKEN units (prompt length +
        # requested max_new_tokens): a single huge request can't slip in
        # as "one row".  Default scales the row budget by a typical
        # per-request token footprint.
        self.generate_token_budget = (
            generate_token_budget if generate_token_budget is not None
            else 32 * max_queue)
        self.admission = AdmissionController(
            max_queue=max_queue, bulk_fraction=bulk_fraction,
            default_deadline_ms=default_deadline_ms,
            plane_budgets={"generate": self.generate_token_budget})
        self.coalescer: Optional[BatchCoalescer] = None
        self.generation: Optional[GenerationService] = None
        if coalesce and (ensemble is not None or manager is not None):
            buckets = (ensemble.batch_buckets if ensemble is not None
                       else BucketSpec.pow2(manager.max_batch))
            self.coalescer = BatchCoalescer(
                self._coalesced_forward, buckets,
                max_wait_ms=max_wait_ms, max_rows=max_coalesce_rows)
        if coalesce and (engine is not None or manager is not None):
            self.generation = GenerationService(
                engine, num_slots=num_slots,
                max_pending=max(num_slots, max_queue),
                max_stream_buffer=max_stream_buffer)
            if manager is not None:
                manager.attach_generation(self.generation)

    @property
    def ensemble(self) -> Optional[Ensemble]:
        """The default-alias ensemble (manager-backed or static)."""
        if self.manager is not None:
            return (self.manager.ensemble_for() if self.manager.ready
                    else None)
        return self._ensemble

    def _coalesced_forward(self, batch, alias, ctxs=None):
        """Coalescer's forward: route one merged group to its target,
        handing the group's RequestContexts to the lifecycle manager's
        per-version traffic accounting."""
        if self.manager is not None:
            return self.manager.forward(batch, alias, ctxs)
        return self._ensemble.forward(batch)

    def close(self) -> None:
        """Stop background dispatch threads (idempotent)."""
        self._closing = True
        if self.coalescer is not None:
            self.coalescer.close()
            self.coalescer = None
        if self.generation is not None:
            self.generation.close()
            self.generation = None

    # --- readiness ------------------------------------------------------------

    def ready(self) -> Dict[str, Any]:
        """Readiness probe payload; raises 503 while not servable."""
        if self._closing:
            raise api.ApiError(503, "shutting down")
        if self.coalescer is not None and not self.coalescer.alive:
            raise api.ApiError(503, "coalescer dispatch thread not alive")
        if self.manager is not None:
            if not self.manager.ready:
                raise api.ApiError(503, "no models loaded yet")
        elif (self._ensemble is None and self.engine is None
              and len(self.registry) == 0):
            raise api.ApiError(503, "no models loaded yet")
        return {"status": "ready", "models": len(self.registry),
                "coalescing": self.coalescer is not None}

    # --- route handlers ------------------------------------------------------

    def handle(self, method: str, path: str, body: bytes,
               headers: Optional[Dict[str, str]] = None) -> Dict[str, Any]:
        with self._stats_lock:
            self.request_count += 1
        t0 = time.perf_counter()
        try:
            return self._route(method, path, body, headers, t0)
        finally:
            dt = time.perf_counter() - t0
            with self._stats_lock:
                st = self._route_stats.setdefault(
                    f"{method} {path}", {"count": 0, "total_s": 0.0,
                                         "max_s": 0.0})
                st["count"] += 1
                st["total_s"] += dt
                st["max_s"] = max(st["max_s"], dt)

    def _route(self, method: str, path: str, body: bytes,
               headers: Optional[Dict[str, str]] = None,
               arrival: Optional[float] = None) -> Dict[str, Any]:
        if method == "GET" and path == "/health":
            return {"status": "ok", "requests": self.request_count}
        if method == "GET" and path == "/healthz":
            return self.ready()
        if method == "GET" and path == "/metrics":
            return self._metrics()
        if method == "GET" and path == "/v1/models":
            return {"models": self.registry.describe(),
                    "ensemble_size": (len(self.ensemble.members)
                                      if self.ensemble else 0)}
        if path.startswith("/v1/models/"):
            return self._model_admin(method, path[len("/v1/models/"):],
                                     body)
        if method == "GET" and path == "/v1/engines":
            return self._engines_status()
        if path.startswith("/v1/engines/"):
            return self._engine_admin(method, path[len("/v1/engines/"):],
                                      body)
        if method == "POST" and path == "/v1/infer":
            req = api.parse_request(body)
            return self._infer(req, self._context(req, headers, arrival))
        if method == "POST" and path == "/v1/detect":
            req = api.parse_request(body)
            return self._detect(req, self._context(req, headers, arrival))
        if method == "POST" and path == "/v1/generate":
            req = api.parse_request(body)
            return self._generate(req, self._context(req, headers, arrival))
        raise api.ApiError(404, f"no route {method} {path}")

    # --- request plane --------------------------------------------------------

    def _context(self, req: Dict[str, Any],
                 headers: Optional[Dict[str, str]],
                 arrival: Optional[float]) -> RequestContext:
        try:
            return self.admission.context(req, headers, arrival_s=arrival)
        except ValueError as e:
            raise api.ApiError(400, str(e)) from None

    @staticmethod
    def _shed_to_api(e: ShedError) -> api.ApiError:
        return api.ApiError(
            429, str(e),
            headers={"Retry-After": format(e.retry_after_s, ".3f")})

    def _admit(self, plane: str, ctx: RequestContext, cost: int):
        try:
            return self.admission.admit(plane, ctx, cost)
        except ShedError as e:
            raise self._shed_to_api(e) from None
        except DeadlineError as e:
            raise api.ApiError(504, str(e)) from None

    def _metrics(self) -> Dict[str, Any]:
        with self._stats_lock:
            routes = {
                k: {"count": v["count"],
                    "mean_ms": 1e3 * v["total_s"] / max(v["count"], 1),
                    "max_ms": 1e3 * v["max_s"]}
                for k, v in self._route_stats.items()}
            requests = self.request_count
        out = {"uptime_s": time.time() - self._t0,
               "requests": requests, "routes": routes}
        if self.coalescer is not None:
            out["coalesce"] = self.coalescer.stats()
        if self.ensemble is not None:
            out["ensemble_compiles"] = {
                str(b): c
                for b, c in sorted(self.ensemble.compile_counts.items())}
        if self.manager is not None:
            out["lifecycle"] = self.manager.stats()
        if self.generation is not None:
            out["generate"] = self.generation.stats()
        out["admission"] = self.admission.stats()
        return out

    # --- lifecycle admin surface ---------------------------------------------

    def _model_admin(self, method: str, rest: str,
                     body: bytes) -> Dict[str, Any]:
        name, _, action = rest.partition("/")
        # member names may contain '#' (e.g. "yi-9b#0"), which clients must
        # percent-encode — decode the path segment here
        name = urllib.parse.unquote(name)
        if not name:
            raise api.ApiError(404, "missing model name")
        if method == "GET" and not action:
            return self._model_status(name)
        if method != "POST" or action not in ("load", "unload", "rollback",
                                              "gc"):
            raise api.ApiError(404,
                               f"no route {method} /v1/models/{rest}")
        mgr = self._require_manager()
        req = api.parse_request(body)
        version = api.opt_int(req, "version", 0) or None
        alias = req.get("alias")
        try:
            if action == "load":
                return mgr.load(name, version, alias=alias,
                                warm=bool(req.get("warm", True)))
            if action == "unload":
                return mgr.unload(name, version)
            if action == "gc":
                keep = api.opt_int(req, "keep_last_n", 0)
                if keep < 1:
                    raise api.ApiError(
                        400, "'keep_last_n' must be an integer >= 1")
                return mgr.gc(name, keep)
            return mgr.rollback(name, alias=alias,
                                warm=bool(req.get("warm", True)))
        except StoreError as e:
            raise api.ApiError(404, str(e)) from None
        except KeyError as e:
            raise api.ApiError(404, str(e)) from None
        except LifecycleError as e:
            raise api.ApiError(409, str(e)) from None

    # --- generation-engine admin surface --------------------------------------

    def _engines_status(self) -> Dict[str, Any]:
        gen = self.generation
        if gen is None:
            return {"aliases": {}, "ready": False}
        stats = gen.stats()
        return {"aliases": {a: e["engine"]
                            for a, e in stats["engines"].items()},
                "ready": gen.ready}

    def _engine_admin(self, method: str, rest: str,
                      body: bytes) -> Dict[str, Any]:
        name, _, action = rest.partition("/")
        name = urllib.parse.unquote(name)
        if not name:
            raise api.ApiError(404, "missing engine name")
        if method != "POST" or action not in ("load", "rollback"):
            raise api.ApiError(404,
                               f"no route {method} /v1/engines/{rest}")
        mgr = self._require_manager()
        req = api.parse_request(body)
        version = api.opt_int(req, "version", 0) or None
        alias = req.get("alias")
        warm = bool(req.get("warm", True))
        try:
            if action == "load":
                return mgr.load_engine(name, version, alias=alias,
                                       warm=warm)
            return mgr.rollback_engine(name, alias=alias, warm=warm)
        except StoreError as e:
            raise api.ApiError(404, str(e)) from None
        except KeyError as e:
            raise api.ApiError(404, str(e)) from None
        except LifecycleError as e:
            raise api.ApiError(409, str(e)) from None

    def _model_status(self, name: str) -> Dict[str, Any]:
        if self.manager is not None:
            try:
                return self.manager.status(name)
            except (LifecycleError, StoreError) as e:
                raise api.ApiError(404, str(e)) from None
        try:
            rm = self.registry.get(name)
        except KeyError as e:
            raise api.ApiError(404, str(e)) from None
        return {"name": name, "versions": [],
                "loaded_versions": self.registry.versions(name),
                "active": {}, "meta": {k: v for k, v in rm.meta.items()
                                       if isinstance(v, (str, int, float))}}

    def _require_manager(self) -> ModelManager:
        if self.manager is None:
            raise api.ApiError(
                503, "no lifecycle manager on this endpoint; start it with "
                     "a model store to enable load/unload/rollback")
        return self.manager

    # --- inference routes ----------------------------------------------------

    def _require_ensemble(self, alias: Optional[str] = None) -> Ensemble:
        if self.manager is not None:
            try:
                return self.manager.ensemble_for(alias)
            except LifecycleError as e:
                raise api.ApiError(404, str(e)) from None
        if alias is not None:
            raise api.ApiError(
                400, "per-request 'target' aliases need a lifecycle "
                     "manager on this endpoint")
        if self._ensemble is None:
            raise api.ApiError(503, "no ensemble deployed on this endpoint")
        return self._ensemble

    def _ensemble_logits(self, batch, alias: Optional[str],
                         ctx: RequestContext) -> Dict[str, np.ndarray]:
        """One forward's worth of per-member logits for this request's rows —
        coalesced with concurrent requests (of the same signature AND the
        same alias target) when the coalescer is on.  Admission is charged
        per ROW; a missed deadline surfaces as 504, a full queue as 429."""
        ens = self._require_ensemble(alias)
        rows = next(iter(batch.values())).shape[0]
        ticket = self._admit("infer", ctx, rows)
        try:
            if self.coalescer is not None:
                return self.coalescer.submit(batch, tag=alias, ctx=ctx)
            with self.device_lock:
                if ctx.expired():
                    raise DeadlineError(
                        "deadline exceeded waiting for the device lock")
                if self.manager is not None:
                    return self.manager.forward(batch, alias, [ctx])
                return ens.forward(batch)
        except DeadlineError as e:
            self.admission.deadline_miss(
                "infer", "coalesce" if self.coalescer is not None
                else "device_lock")
            raise api.ApiError(504, str(e)) from None
        except LifecycleError as e:
            raise api.ApiError(404, str(e)) from None
        except KeyError as e:
            raise api.ApiError(400, str(e)) from None
        except ValueError as e:
            raise api.ApiError(400, str(e)) from None
        finally:
            ticket.release()

    def _infer(self, req, ctx: RequestContext) -> Dict[str, Any]:
        alias = req.get("target")
        ens = self._require_ensemble(alias)
        batch = api.inputs_to_batch(req.get("inputs", {}))
        policy = req.get("policy", "soft_vote")
        logits = self._ensemble_logits(batch, alias, ctx)
        try:
            return ens.respond_from_logits(logits, policy=policy)
        except (KeyError, ValueError) as e:
            raise api.ApiError(400, str(e)) from None

    def _detect(self, req, ctx: RequestContext) -> Dict[str, Any]:
        alias = req.get("target")
        ens = self._require_ensemble(alias)
        batch = api.inputs_to_batch(req.get("inputs", {}))
        if "positive_class" not in req:
            raise api.ApiError(400, "'positive_class' is required")
        logits = self._ensemble_logits(batch, alias, ctx)
        out = ens.detect_from_logits(
            logits, positive_class=int(req["positive_class"]),
            threshold=float(req.get("threshold", 0.5)),
            policy=req.get("policy", "or"))
        resp = {f"model_{i}": v
                for i, v in enumerate(out["members"].values())}
        resp["ensemble"] = out["ensemble"]
        resp["policy"] = req.get("policy", "or")
        return resp

    def _generate(self, req, ctx: RequestContext):
        prompts = req.get("prompts")
        if not prompts or not isinstance(prompts, list):
            raise api.ApiError(400, "'prompts' must be a list of token lists")
        sampling = api.parse_sampling(req)
        alias = req.get("target")
        if req.get("stream"):
            return self._generate_stream(prompts, sampling, alias, ctx)
        cost = sum(len(p) for p in prompts if isinstance(p, list)) \
            + len(prompts) * sampling.max_new_tokens
        ticket = self._admit("generate", ctx, cost)
        try:
            if self.generation is not None and (self.generation.ready
                                                or alias is not None):
                res = self.generation.generate(prompts, sampling,
                                               alias=alias, ctx=ctx)
            elif self.engine is not None:
                if alias is not None:
                    raise api.ApiError(
                        400, "per-request 'target' aliases need a "
                             "generation service on this endpoint")
                with self.device_lock:
                    if ctx.expired():
                        self.admission.deadline_miss("generate",
                                                     "device_lock")
                        raise api.ApiError(
                            504, "deadline exceeded waiting for the "
                                 "device lock")
                    res = self.engine.generate(prompts, sampling=sampling)
            else:
                raise api.ApiError(503, "no generation engine deployed")
        except ShedError as e:
            raise self._shed_to_api(e) from None
        except GenerationError as e:
            raise api.ApiError(404, str(e)) from None
        except (ValueError, TypeError) as e:
            raise api.ApiError(400, str(e)) from None
        finally:
            ticket.release()
        if res.finish_reasons and all(r == "deadline"
                                      for r in res.finish_reasons):
            self.admission.deadline_miss("generate", "scheduler")
            raise api.ApiError(
                504, f"deadline exceeded before decode "
                     f"({ctx.trace_id or 'request'})")
        return {"outputs": res.tokens, "steps": res.steps,
                "prompt_lengths": res.prompt_lengths,
                "finish_reasons": res.finish_reasons}

    def _generate_stream(self, prompts, sampling, alias,
                         ctx: RequestContext) -> api.StreamingResponse:
        if self.generation is None or not (self.generation.ready
                                           or alias is not None):
            raise api.ApiError(
                503, "streaming needs the scheduler-backed generation "
                     "service (engine deployed, coalesce=True)")
        if len(prompts) != 1:
            raise api.ApiError(
                400, "streaming supports exactly one prompt per request")
        cost = (len(prompts[0]) if isinstance(prompts[0], list) else 1) \
            + sampling.max_new_tokens
        ticket = self._admit("generate", ctx, cost)
        try:
            # the ticket's budget hold lives as long as the stream: it is
            # released by the terminal event or by disconnect-cancellation
            stream = self.generation.stream(prompts[0], sampling,
                                            alias=alias, ctx=ctx,
                                            on_finish=ticket.release)
        except ShedError as e:
            ticket.release()
            raise self._shed_to_api(e) from None
        except GenerationError as e:
            ticket.release()
            raise api.ApiError(404, str(e)) from None
        except (ValueError, TypeError) as e:
            ticket.release()
            raise api.ApiError(400, str(e)) from None
        except BaseException:
            ticket.release()
            raise
        return api.StreamingResponse(stream.events(),
                                     on_disconnect=stream.cancel)


_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            409: "Conflict", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}

# request-plane headers the lean parser captures (already lowercase)
_PLANE_HEADERS = (b"x-flexserve-priority", b"x-flexserve-deadline-ms",
                  b"x-flexserve-client", b"x-request-id")


def make_handler(app: FlexServeApp):
    class Handler(socketserver.StreamRequestHandler):
        """Lean HTTP/1.1 keep-alive handler.

        The stdlib BaseHTTPRequestHandler parses headers through
        email.parser and writes responses in several syscalls — measurable
        per-request cost once the device work is coalesced away.  Serving
        needs exactly: request line, Content-Length, Connection; the
        response goes out as ONE write (which also avoids Nagle/delayed-ACK
        stalls when a coalesced batch releases many responses at once).
        """

        disable_nagle_algorithm = True
        timeout = 120

        def handle(self):
            try:
                while self._one_request():
                    pass
            except (ConnectionError, TimeoutError, OSError):
                pass                          # client went away

        def _one_request(self) -> bool:
            line = self.rfile.readline(65537)
            if not line or line in (b"\r\n", b"\n"):
                return False
            parts = line.split()
            if len(parts) < 2:
                return False
            method, path = parts[0].decode("latin-1"), \
                parts[1].decode("latin-1")
            length, keep = 0, True
            plane: Optional[Dict[str, str]] = None
            while True:
                h = self.rfile.readline(65537)
                if h in (b"\r\n", b"\n", b""):
                    break
                key, _, val = h.partition(b":")
                key = key.strip().lower()
                if key == b"content-length":
                    try:
                        length = int(val)
                    except ValueError:
                        self._reply(400, b'{"error": "bad Content-Length"}',
                                    False)
                        return False
                elif key == b"connection":
                    keep = b"close" not in val.lower()
                elif key in _PLANE_HEADERS:
                    if plane is None:
                        plane = {}
                    plane[key.decode("latin-1")] = \
                        val.strip().decode("latin-1")
            body = self.rfile.read(length) if length else b""
            extra = None
            try:
                status, payload = 200, app.handle(method, path, body, plane)
            except api.ApiError as e:
                status, payload, extra = e.status, {"error": e.message}, \
                    e.headers
            except Exception as e:          # noqa: BLE001 — server boundary
                status, payload = 500, {"error": f"{type(e).__name__}: {e}"}
            if isinstance(payload, api.StreamingResponse):
                return self._stream_reply(payload, keep)
            data = api.encode_response(payload)
            self._reply(status, data, keep, extra)
            return keep

        def _reply(self, status: int, data: bytes, keep: bool,
                   extra: Optional[Dict[str, str]] = None) -> None:
            lines = "".join(f"{k}: {v}\r\n" for k, v in (extra or {}).items())
            head = (f"HTTP/1.1 {status} {_REASONS.get(status, '')}\r\n"
                    f"Content-Type: application/json\r\n"
                    f"Content-Length: {len(data)}\r\n"
                    f"{lines}"
                    f"Connection: {'keep-alive' if keep else 'close'}\r\n"
                    f"\r\n").encode("latin-1")
            self.wfile.write(head + data)     # one syscall, one segment

        def _stream_reply(self, resp: api.StreamingResponse,
                          keep: bool) -> bool:
            """Write a token stream as chunked transfer encoding — one
            NDJSON event per chunk, flushed as it decodes, so the client
            sees the first token long before the stream finishes.  A
            failed write means the client went away: cancel the request
            (freeing its decode slot) and drop the connection."""
            head = (f"HTTP/1.1 200 OK\r\n"
                    f"Content-Type: application/x-ndjson\r\n"
                    f"Transfer-Encoding: chunked\r\n"
                    f"Connection: {'keep-alive' if keep else 'close'}\r\n"
                    f"\r\n").encode("latin-1")
            try:
                self.wfile.write(head)
                for event in resp.events:
                    data = api.encode_response(event) + b"\n"
                    # chunk = size line + payload (wfile is unbuffered:
                    # one write, one segment — the flush per token)
                    self.wfile.write(b"%x\r\n%s\r\n" % (len(data), data))
                self.wfile.write(b"0\r\n\r\n")
                return keep
            except (ConnectionError, TimeoutError, OSError):
                resp.disconnect()             # cancel: free the decode slot
                return False

    return Handler


class _ThreadingServer(socketserver.ThreadingTCPServer):
    daemon_threads = True
    allow_reuse_address = True


class FlexServeServer:
    """Owns the listening socket; ``start()`` serves on a daemon thread."""

    def __init__(self, app: FlexServeApp, host: str = "127.0.0.1",
                 port: int = 0):
        self.app = app
        self.httpd = _ThreadingServer((host, port), make_handler(app))

    @property
    def address(self):
        return self.httpd.server_address

    def start(self, wait_ready: bool = True,
              timeout: float = 10.0) -> "FlexServeServer":
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        if wait_ready:
            self.wait_ready(timeout)
        return self

    def wait_ready(self, timeout: float = 10.0) -> bool:
        """Poll GET /healthz over real HTTP until the endpoint reports
        ready (the same probe an orchestrator would use); returns whether
        readiness was observed within the timeout."""
        from repro.serving.client import FlexServeClient
        host, port = self.address
        client = FlexServeClient(host, port, timeout=max(timeout, 1.0))
        deadline = time.time() + timeout
        try:
            while time.time() < deadline:
                try:
                    client.healthz()
                    return True
                except (RuntimeError, OSError):
                    time.sleep(0.02)
        finally:
            client.close()
        return False

    def stop(self) -> None:
        self.app._closing = True
        self.httpd.shutdown()
        self.httpd.server_close()
        self.app.close()
