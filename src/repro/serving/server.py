"""FlexServe REST server — a lean thread-per-connection HTTP front-end.

The paper wraps its ensemble in Flask behind a Gunicorn WSGI server; Flask
is not available in this offline container, so the same architecture is
built on ``socketserver``: a threaded front-end accepts concurrent client
connections (the Gunicorn-worker analogue for IO), with a hand-rolled
keep-alive HTTP/1.1 handler whose per-request cost is a fraction of
``http.server``'s.

Accelerator work is NOT serialized per request.  Ensemble routes
(/v1/infer, /v1/detect) funnel through a ``BatchCoalescer`` that merges
concurrent requests' rows into one bucketed forward; /v1/generate goes
through a ``SchedulerService`` that admits prompts into continuous-batching
decode slots.  ``coalesce=False`` restores the legacy one-request-per-
forward behavior behind a global device lock (kept as the benchmark
baseline).

With a ``ModelManager`` attached, the endpoint gains a lifecycle admin
surface (GET /v1/models/{name}, POST .../load /unload /rollback) and
per-request version-alias targeting on the inference routes — hot swaps
happen under live traffic with zero dropped requests.

Endpoints are defined in repro.serving.api.
"""

from __future__ import annotations

import socketserver
import threading
import time
import urllib.parse
from typing import Any, Dict, Optional

import numpy as np

from repro.core.batching import BucketSpec
from repro.core.engine import InferenceEngine
from repro.core.ensemble import Ensemble
from repro.core.faults import (ZERO_FAULT_STATS, FaultInjector,
                               InjectedFault)
from repro.core.registry import ModelRegistry
from repro.core.slo import (ZERO_SLO, SLIStore, SLOController, UsageLedger,
                            load_policies)
from repro.serving import api
from repro.serving.admission import (AdmissionController, DeadlineError,
                                     RequestContext, ShedError)
from repro.serving.coalesce import BatchCoalescer
from repro.serving.generate import GenerationError, GenerationService
from repro.serving.lifecycle import LifecycleError, ModelManager
from repro.serving.modelstore import StoreError
from repro.serving.replica import ZERO_REPLICA_STATS
from repro.serving.telemetry import (DeviceProfiler, FlightRecorder,
                                     prometheus_exposition)

# lifecycle section served when no manager is attached, so the /metrics
# key set (and the Prometheus exposition) is identical either way
_ZERO_LIFECYCLE: Dict[str, Any] = {
    "loads": 0, "unloads": 0, "swaps": 0, "rollbacks": 0,
    "engine_loads": 0, "engine_rollbacks": 0,
    "engine_promotes": 0, "engine_demotes": 0, "gc_runs": 0,
    "last_warm_ms": 0.0, "warm_total_ms": 0.0, "per_version": {},
    "aliases": {}, "engine_aliases": {}}


class FlexServeApp:
    """Bundles a registry, an optional ensemble/manager, and an engine.

    ``max_wait_ms`` / ``max_coalesce_rows`` tune the coalescer (how long
    the dispatcher lingers for more rows — ``None`` derives the linger
    adaptively from the observed arrival rate — and the rows-per-forward
    cap); ``num_slots`` sizes each continuous-batching decode pool.  Pass
    a ``manager`` instead of a static ``ensemble`` to serve store-backed,
    hot-swappable models; with a manager attached, generation engines are
    versioned and hot-swappable too (POST /v1/engines/{name}/load).

    ``replicas > 1`` runs the generate plane as a health-checked
    :class:`~repro.serving.replica.ReplicaPool` — N independent decode
    schedulers over the shared engine, with automatic cordon/restart and
    transparent failover (see GET /v1/replicas).  ``fault_config``
    accepts anything :meth:`FaultInjector.load` does (path / dict /
    injector) and arms the deterministic chaos sites across every layer;
    ``replica_options`` passes pool tuning knobs (health thresholds)
    straight through.
    """

    def __init__(self, registry: Optional[ModelRegistry] = None,
                 ensemble: Optional[Ensemble] = None,
                 engine: Optional[InferenceEngine] = None, *,
                 manager: Optional[ModelManager] = None,
                 coalesce: bool = True,
                 max_wait_ms: Optional[float] = None,
                 max_coalesce_rows: Optional[int] = None,
                 num_slots: int = 4,
                 max_queue: int = 64,
                 bulk_fraction: float = 0.5,
                 default_deadline_ms: Optional[float] = None,
                 max_stream_buffer: int = 32,
                 generate_token_budget: Optional[int] = None,
                 trace: bool = True,
                 flight_recorder_size: int = 256,
                 profile_dir: Optional[str] = None,
                 slo_policies: Any = None,
                 slo_interval_s: float = 2.0,
                 sli_bucket_s: float = 10.0,
                 sli_n_buckets: int = 60,
                 client_weights: Optional[Dict[str, float]] = None,
                 replicas: int = 1,
                 fault_config: Any = None,
                 replica_options: Optional[Dict[str, Any]] = None):
        if manager is not None and ensemble is not None:
            raise ValueError("pass either a static ensemble or a manager")
        self.manager = manager
        # one injector shared by every layer (scheduler drivers, lifecycle
        # loads, the stream writer) so a single config file describes the
        # whole chaos drill
        self.faults: Optional[FaultInjector] = FaultInjector.load(
            fault_config)
        if manager is not None and self.faults is not None \
                and getattr(manager, "faults", None) is None:
            manager.faults = self.faults
        self.registry = (manager.registry if manager is not None
                         else registry or ModelRegistry())
        self._ensemble = ensemble
        self.engine = engine
        self.device_lock = threading.Lock()
        self.request_count = 0
        # monotonic for uptime arithmetic; the wall time is only reported
        self._t0 = time.monotonic()
        self._started_unix = time.time()
        # SLI/usage aggregation rides the flight recorder's completion
        # hook: both stay zeroed (but present in /metrics) with tracing
        # off, so the schema is identical either way
        self.sli = SLIStore(bucket_s=sli_bucket_s, n_buckets=sli_n_buckets)
        self.usage = UsageLedger()
        self.slo: Optional[SLOController] = None
        self.recorder: Optional[FlightRecorder] = (
            FlightRecorder(capacity=flight_recorder_size,
                           on_complete=self._ingest_trace)
            if trace else None)
        self.profiler: Optional[DeviceProfiler] = (
            DeviceProfiler(artifact_dir=profile_dir)
            if profile_dir is not None else None)
        self._closing = False
        self._route_stats: Dict[str, Dict[str, float]] = {}
        self._stats_lock = threading.Lock()
        # the generate plane is budgeted in TOKEN units (prompt length +
        # requested max_new_tokens): a single huge request can't slip in
        # as "one row".  Default scales the row budget by a typical
        # per-request token footprint.
        self.generate_token_budget = (
            generate_token_budget if generate_token_budget is not None
            else 32 * max_queue)
        self.admission = AdmissionController(
            max_queue=max_queue, bulk_fraction=bulk_fraction,
            default_deadline_ms=default_deadline_ms,
            plane_budgets={"generate": self.generate_token_budget},
            client_weights=client_weights)
        self.coalescer: Optional[BatchCoalescer] = None
        self.generation: Optional[GenerationService] = None
        if coalesce and (ensemble is not None or manager is not None):
            buckets = (ensemble.batch_buckets if ensemble is not None
                       else BucketSpec.pow2(manager.max_batch))
            self.coalescer = BatchCoalescer(
                self._coalesced_forward, buckets,
                max_wait_ms=max_wait_ms, max_rows=max_coalesce_rows)
        if coalesce and (engine is not None or manager is not None):
            self.generation = GenerationService(
                engine, num_slots=num_slots,
                max_pending=max(num_slots, max_queue),
                max_stream_buffer=max_stream_buffer,
                client_weights=client_weights,
                num_replicas=replicas,
                faults=self.faults,
                replica_options=replica_options)
            if manager is not None:
                manager.attach_generation(self.generation)
        policies = load_policies(slo_policies) if slo_policies else []
        if policies:
            self.slo = SLOController(
                self.sli, policies,
                resolve=self._slo_resolve, promote=self._slo_promote,
                rollback=self._slo_rollback, recorder=self.recorder,
                interval_s=slo_interval_s)
            self.slo.start()

    @property
    def ensemble(self) -> Optional[Ensemble]:
        """The default-alias ensemble (manager-backed or static)."""
        if self.manager is not None:
            return (self.manager.ensemble_for() if self.manager.ready
                    else None)
        return self._ensemble

    def _coalesced_forward(self, batch, alias, ctxs=None):
        """Coalescer's forward: route one merged group to its target,
        handing the group's RequestContexts to the lifecycle manager's
        per-version traffic accounting."""
        if self.manager is not None:
            return self.manager.forward(batch, alias, ctxs)
        return self._ensemble.forward(batch)

    def close(self) -> None:
        """Stop background dispatch threads (idempotent)."""
        self._closing = True
        if self.slo is not None:
            self.slo.close()
        if self.coalescer is not None:
            self.coalescer.close()
            self.coalescer = None
        if self.generation is not None:
            self.generation.close()
            self.generation = None

    # --- SLO autopilot glue ---------------------------------------------------

    def _ingest_trace(self, tr) -> None:
        """FlightRecorder completion hook: fold one sealed trace into the
        windowed SLIs and the per-client/per-version usage ledger.  499
        (client cancelled) is not an availability error; a deadline miss
        is either a 504 or a request whose streams all hit 'deadline'."""
        if tr.plane == "slo":                 # autopilot audit traces
            return
        status = tr.status if tr.status is not None else 200
        end_s = tr.end_s if tr.end_s is not None else tr.start_s
        ttft_ms = None
        for ev in tr.events:
            if ev.get("name") == "first_token":
                ttft_ms = 1e3 * (ev["t"] - tr.start_s)
                break
        error = status >= 500
        miss = status == 504 or tr.finish_reason == "deadline"
        version = tr.attrs.get("version")
        self.sli.ingest(plane=tr.plane, client=tr.client, version=version,
                        latency_ms=1e3 * (end_s - tr.start_s), error=error,
                        deadline_miss=miss, ttft_ms=ttft_ms)
        self.usage.ingest(plane=tr.plane, client=tr.client, version=version,
                          error=error, counters=tr.counters)

    def _slo_resolve(self, alias: str) -> Optional[str]:
        """Version label currently serving ``alias`` (None when unknown)."""
        if self.manager is not None:
            label = self.manager.engine_version_label(alias)
            if label is not None:
                return label
        if self.generation is not None:
            try:
                return self.generation.entry_for(alias).label
            except GenerationError:
                return None
        return None

    def _slo_promote(self, policy) -> Dict[str, Any]:
        if self.manager is not None and \
                self.manager.engine_version_label(policy.alias) is not None:
            return self.manager.promote_engine(policy.alias,
                                               to_alias=policy.promote_to)
        if self.generation is None:
            raise GenerationError("no generation service to actuate")
        return self.generation.repoint(policy.alias, policy.promote_to)

    def _slo_rollback(self, policy) -> Dict[str, Any]:
        if self.manager is not None and \
                self.manager.engine_version_label(policy.promote_to) \
                is not None:
            return self.manager.demote_engine(policy.alias,
                                              to_alias=policy.promote_to)
        if self.generation is None:
            raise GenerationError("no generation service to actuate")
        return self.generation.repoint(policy.promote_to, policy.alias)

    # --- readiness ------------------------------------------------------------

    def ready(self) -> Dict[str, Any]:
        """Readiness probe payload; raises 503 while not servable.

        With a generation service attached the probe aggregates replica
        health: the payload reports the ready count and the cordoned set,
        and the endpoint goes 503 the moment ZERO replicas can take work
        — a load balancer drains it before clients see hard failures."""
        if self._closing:
            raise api.ApiError(503, "shutting down")
        if self.coalescer is not None and not self.coalescer.alive:
            raise api.ApiError(503, "coalescer dispatch thread not alive")
        if self.manager is not None:
            if not self.manager.ready:
                raise api.ApiError(503, "no models loaded yet")
        elif (self._ensemble is None and self.engine is None
              and len(self.registry) == 0):
            raise api.ApiError(503, "no models loaded yet")
        out = {"status": "ready", "models": len(self.registry),
               "coalescing": self.coalescer is not None}
        if self.generation is not None and self.generation.ready:
            rs = self.generation.replica_summary()
            out["replicas"] = {"count": rs["count"], "ready": rs["ready"],
                               "cordoned": list(rs["cordoned_ids"])}
            if rs["count"] > 0 and rs["ready"] == 0:
                raise api.ApiError(
                    503, f"no ready replicas ({rs['count']} configured: "
                         f"{rs['warming']} warming, {rs['cordoned']} "
                         f"cordoned, {rs['restarting']} restarting)")
        return out

    # --- route handlers ------------------------------------------------------

    @staticmethod
    def _stats_key(method: str, path: str) -> str:
        """Route-stats bucket: query string stripped, parametric path
        segments collapsed so the stats dict stays bounded."""
        path = path.partition("?")[0]
        if path.startswith("/v1/trace/"):
            path = "/v1/trace/{id}"
        return f"{method} {path}"

    def handle(self, method: str, path: str, body: bytes,
               headers: Optional[Dict[str, str]] = None) -> Dict[str, Any]:
        with self._stats_lock:
            self.request_count += 1
        t0 = time.perf_counter()
        try:
            return self._route(method, path, body, headers, t0)
        finally:
            dt = time.perf_counter() - t0
            with self._stats_lock:
                st = self._route_stats.setdefault(
                    self._stats_key(method, path),
                    {"count": 0, "total_s": 0.0, "max_s": 0.0})
                st["count"] += 1
                st["total_s"] += dt
                st["max_s"] = max(st["max_s"], dt)

    def _route(self, method: str, path: str, body: bytes,
               headers: Optional[Dict[str, str]] = None,
               arrival: Optional[float] = None) -> Dict[str, Any]:
        path, _, qs = path.partition("?")
        query = dict(urllib.parse.parse_qsl(qs)) if qs else {}
        if method == "GET" and path == "/health":
            return {"status": "ok", "requests": self.request_count}
        if method == "GET" and path == "/healthz":
            return self.ready()
        if method == "GET" and path == "/metrics":
            return self._metrics(fmt=query.get("format", "json"))
        if method == "GET" and path.startswith("/v1/trace/"):
            return self._trace_lookup(path[len("/v1/trace/"):])
        if method == "GET" and path == "/v1/traces":
            return self._traces_index(query)
        if method == "GET" and path == "/v1/usage":
            return self._usage(query)
        if method == "GET" and path == "/v1/slo":
            return self._slo_status(query)
        if path == "/v1/debug/profile":
            return self._profile_admin(method, body)
        if method == "GET" and path == "/v1/models":
            return {"models": self.registry.describe(),
                    "ensemble_size": (len(self.ensemble.members)
                                      if self.ensemble else 0)}
        if path.startswith("/v1/models/"):
            return self._model_admin(method, path[len("/v1/models/"):],
                                     body)
        if method == "GET" and path == "/v1/engines":
            return self._engines_status()
        if path.startswith("/v1/engines/"):
            return self._engine_admin(method, path[len("/v1/engines/"):],
                                      body)
        if method == "GET" and path == "/v1/replicas":
            return self._replicas_status(query)
        if path.startswith("/v1/replicas/"):
            return self._replica_admin(method,
                                       path[len("/v1/replicas/"):], body)
        if method == "POST" and path == "/v1/infer":
            return self._traced("infer", body, headers, arrival,
                                self._infer)
        if method == "POST" and path == "/v1/detect":
            return self._traced("detect", body, headers, arrival,
                                self._detect)
        if method == "POST" and path == "/v1/generate":
            return self._traced("generate", body, headers, arrival,
                                self._generate)
        raise api.ApiError(404, f"no route {method} {path}")

    def _traced(self, plane: str, body: bytes,
                headers: Optional[Dict[str, str]],
                arrival: Optional[float], fn):
        """Run a request-plane route under the flight recorder: begin a
        trace keyed by the request's trace_id, record the HTTP parse span,
        attach the live trace to the RequestContext (every downstream
        layer picks it up from there), and seal it when the route returns.
        Streaming responses are sealed by the stream's terminal event
        instead; error paths (shed, deadline, 5xx) seal here so they stay
        queryable via GET /v1/trace/{id}."""
        req = api.parse_request(body)
        ctx = self._context(req, headers, arrival)
        tr = None
        if self.recorder is not None:
            tr = self.recorder.begin(ctx.trace_id, plane,
                                     client=ctx.client,
                                     priority=ctx.priority,
                                     start_s=ctx.arrival_s)
            ctx.trace = tr
            tr.span("http_parse", ctx.arrival_s, time.perf_counter(),
                    bytes=len(body))
        try:
            out = fn(req, ctx)
        except api.ApiError as e:
            if tr is not None:
                e.headers.setdefault("X-Request-Id", ctx.trace_id)
                tr.finish(status=e.status, error=e.message)
            raise
        except Exception as e:              # noqa: BLE001 — seal, re-raise
            if tr is not None:
                tr.finish(status=500, error=f"{type(e).__name__}: {e}")
            raise
        if isinstance(out, api.StreamingResponse):
            if tr is not None:
                out.headers.setdefault("X-Request-Id", ctx.trace_id)
            return out
        if tr is not None:
            tr.finish(status=200)
            return api.JsonResponse(out, {"X-Request-Id": ctx.trace_id})
        return out

    # --- telemetry surface ----------------------------------------------------

    def _trace_lookup(self, trace_id: str) -> Dict[str, Any]:
        if self.recorder is None:
            raise api.ApiError(404, "tracing is disabled on this endpoint")
        trace_id = urllib.parse.unquote(trace_id)
        tr = self.recorder.get(trace_id)
        if tr is None:
            raise api.ApiError(
                404, f"no trace {trace_id!r} (evicted from the flight "
                     f"recorder, or never admitted)")
        return tr.snapshot()

    def _traces_index(self,
                      query: Optional[Dict[str, str]] = None
                      ) -> Dict[str, Any]:
        if self.recorder is None:
            raise api.ApiError(404, "tracing is disabled on this endpoint")
        query = query or {}
        try:
            limit = int(query.get("limit", 20))
            min_ms = (float(query["min_duration_ms"])
                      if "min_duration_ms" in query else None)
            want_status = (int(query["status"]) if "status" in query
                           else None)
        except ValueError as e:
            raise api.ApiError(400, f"bad traces filter: {e}") from None
        if limit < 1:
            raise api.ApiError(400, "'limit' must be an integer >= 1")
        want_client = query.get("client")
        filtered = (want_status is not None or want_client is not None
                    or min_ms is not None)
        # with filters active, scan the whole ring so matches older than
        # the newest `limit` rows still surface
        rows = self.recorder.recent(
            n=self.recorder.capacity if filtered else limit)
        if want_status is not None:
            rows = [r for r in rows if r["status"] == want_status]
        if want_client is not None:
            rows = [r for r in rows if r["client"] == want_client]
        if min_ms is not None:
            rows = [r for r in rows if r["duration_ms"] >= min_ms]
        return {"telemetry": self.recorder.stats(),
                "in_flight": self.recorder.in_flight(),
                "recent": rows[:limit]}

    def _usage(self, query: Dict[str, str]) -> Dict[str, Any]:
        return self.usage.snapshot(client=query.get("client"),
                                   version=query.get("version"))

    def _slo_status(self, query: Dict[str, str]) -> Dict[str, Any]:
        try:
            window_s = float(query.get("window_s", 60.0))
        except ValueError as e:
            raise api.ApiError(400, f"bad slo query: {e}") from None
        if self.slo is not None:
            return {"enabled": True,
                    **self.slo.status(window_s=window_s)}
        return {"enabled": False, **dict(ZERO_SLO), "policies": [],
                "decisions": [], "sli": self.sli.snapshot(window_s)}

    def _profile_admin(self, method: str, body: bytes) -> Dict[str, Any]:
        if self.profiler is None:
            raise api.ApiError(
                503, "profiling is disabled; start the endpoint with a "
                     "--profile-dir to enable it")
        if method == "GET":
            return self.profiler.status()
        if method != "POST":
            raise api.ApiError(404,
                               f"no route {method} /v1/debug/profile")
        req = api.parse_request(body)
        duration = api.opt_int(req, "duration_ms", 1000)
        mode = str(req.get("mode", "auto"))
        if mode not in ("auto", "jax", "python"):
            raise api.ApiError(400,
                               "'mode' must be 'auto', 'jax' or 'python'")
        try:
            out = self.profiler.start(duration_ms=duration, mode=mode)
        except RuntimeError as e:
            raise api.ApiError(409, str(e)) from None
        except ValueError as e:
            raise api.ApiError(400, str(e)) from None
        return api.JsonResponse(out, status=202)

    # --- request plane --------------------------------------------------------

    def _context(self, req: Dict[str, Any],
                 headers: Optional[Dict[str, str]],
                 arrival: Optional[float]) -> RequestContext:
        try:
            return self.admission.context(req, headers, arrival_s=arrival)
        except ValueError as e:
            raise api.ApiError(400, str(e)) from None

    @staticmethod
    def _shed_to_api(e: ShedError) -> api.ApiError:
        return api.ApiError(
            429, str(e),
            headers={"Retry-After": format(e.retry_after_s, ".3f")})

    def _admit(self, plane: str, ctx: RequestContext, cost: int):
        try:
            return self.admission.admit(plane, ctx, cost)
        except ShedError as e:
            raise self._shed_to_api(e) from None
        except DeadlineError as e:
            raise api.ApiError(504, str(e)) from None

    def _metrics(self, fmt: str = "json"):
        with self._stats_lock:
            routes = {
                k: {"count": v["count"],
                    "mean_ms": 1e3 * v["total_s"] / max(v["count"], 1),
                    "max_ms": 1e3 * v["max_s"]}
                for k, v in self._route_stats.items()}
            requests = self.request_count
        out = {"uptime_s": time.monotonic() - self._t0,
               "started_unix": self._started_unix,
               "requests": requests, "routes": routes}
        if self.coalescer is not None:
            out["coalesce"] = self.coalescer.stats()
        if self.ensemble is not None:
            out["ensemble_compiles"] = {
                str(b): c
                for b, c in sorted(self.ensemble.compile_counts.items())}
        out["lifecycle"] = (self.manager.stats() if self.manager is not None
                            else dict(_ZERO_LIFECYCLE))
        if self.generation is not None:
            out["generate"] = self.generation.stats()
        out["admission"] = self.admission.stats()
        # always present (zeroed with tracing off) so the /metrics schema
        # — and the Prometheus exposition — is stable across configs
        out["replicas"] = (self.generation.replica_summary()
                           if self.generation is not None
                           else dict(ZERO_REPLICA_STATS))
        out["faults"] = (self.faults.stats() if self.faults is not None
                         else dict(ZERO_FAULT_STATS))
        out["usage"] = self.usage.totals()
        out["slo"] = (self.slo.stats() if self.slo is not None
                      else dict(ZERO_SLO))
        if self.recorder is not None:
            out["telemetry"] = self.recorder.stats()
        if fmt == "prometheus":
            return api.PlainTextResponse(prometheus_exposition(out))
        if fmt != "json":
            raise api.ApiError(400, f"unknown metrics format {fmt!r}")
        return out

    # --- lifecycle admin surface ---------------------------------------------

    def _model_admin(self, method: str, rest: str,
                     body: bytes) -> Dict[str, Any]:
        name, _, action = rest.partition("/")
        # member names may contain '#' (e.g. "yi-9b#0"), which clients must
        # percent-encode — decode the path segment here
        name = urllib.parse.unquote(name)
        if not name:
            raise api.ApiError(404, "missing model name")
        if method == "GET" and not action:
            return self._model_status(name)
        if method != "POST" or action not in ("load", "unload", "rollback",
                                              "gc"):
            raise api.ApiError(404,
                               f"no route {method} /v1/models/{rest}")
        mgr = self._require_manager()
        req = api.parse_request(body)
        version = api.opt_int(req, "version", 0) or None
        alias = req.get("alias")
        try:
            if action == "load":
                return mgr.load(name, version, alias=alias,
                                warm=bool(req.get("warm", True)))
            if action == "unload":
                return mgr.unload(name, version)
            if action == "gc":
                keep = api.opt_int(req, "keep_last_n", 0)
                if keep < 1:
                    raise api.ApiError(
                        400, "'keep_last_n' must be an integer >= 1")
                return mgr.gc(name, keep)
            return mgr.rollback(name, alias=alias,
                                warm=bool(req.get("warm", True)))
        except StoreError as e:
            raise api.ApiError(404, str(e)) from None
        except KeyError as e:
            raise api.ApiError(404, str(e)) from None
        except LifecycleError as e:
            raise api.ApiError(409, str(e)) from None

    # --- generation-engine admin surface --------------------------------------

    def _engines_status(self) -> Dict[str, Any]:
        gen = self.generation
        if gen is None:
            return {"aliases": {}, "ready": False}
        stats = gen.stats()
        return {"aliases": {a: e["engine"]
                            for a, e in stats["engines"].items()},
                "ready": gen.ready}

    def _engine_admin(self, method: str, rest: str,
                      body: bytes) -> Dict[str, Any]:
        name, _, action = rest.partition("/")
        name = urllib.parse.unquote(name)
        if not name:
            raise api.ApiError(404, "missing engine name")
        if method != "POST" or action not in ("load", "rollback"):
            raise api.ApiError(404,
                               f"no route {method} /v1/engines/{rest}")
        mgr = self._require_manager()
        req = api.parse_request(body)
        version = api.opt_int(req, "version", 0) or None
        alias = req.get("alias")
        warm = bool(req.get("warm", True))
        try:
            if action == "load":
                return mgr.load_engine(name, version, alias=alias,
                                       warm=warm)
            return mgr.rollback_engine(name, alias=alias, warm=warm)
        except StoreError as e:
            raise api.ApiError(404, str(e)) from None
        except KeyError as e:
            raise api.ApiError(404, str(e)) from None
        except LifecycleError as e:
            raise api.ApiError(409, str(e)) from None

    # --- replica admin surface ------------------------------------------------

    def _replicas_status(self, query: Dict[str, str]) -> Dict[str, Any]:
        """Per-replica lifecycle states and pool counters.  Works in
        single-service mode too (the one implicit replica is reported),
        so dashboards don't need to know how the endpoint was started."""
        if self.generation is None:
            return dict(ZERO_REPLICA_STATS)
        return self.generation.replica_summary(query.get("target"))

    def _replica_admin(self, method: str, rest: str,
                       body: bytes) -> Dict[str, Any]:
        """POST /v1/replicas/{id}/cordon|uncordon — operator drain
        control.  Cordon is drain-aware (in-flight work finishes in
        place); uncordon restarts the replica first if its driver died."""
        rid_s, _, action = rest.partition("/")
        if method != "POST" or action not in ("cordon", "uncordon"):
            raise api.ApiError(404,
                               f"no route {method} /v1/replicas/{rest}")
        req = api.parse_request(body)
        pool = (self.generation.pool_for(req.get("target"))
                if self.generation is not None else None)
        if pool is None:
            raise api.ApiError(
                409, "no replica pool on this endpoint; start it with "
                     "--replicas > 1 to enable cordon/uncordon")
        try:
            rid = int(rid_s)
        except ValueError:
            raise api.ApiError(404, f"bad replica id {rid_s!r}") from None
        try:
            if action == "cordon":
                reason = str(req.get("reason", "manual cordon"))
                return pool.cordon(rid, reason=reason)
            return pool.uncordon(rid)
        except KeyError as e:
            raise api.ApiError(404, str(e)) from None

    def _model_status(self, name: str) -> Dict[str, Any]:
        if self.manager is not None:
            try:
                return self.manager.status(name)
            except (LifecycleError, StoreError) as e:
                raise api.ApiError(404, str(e)) from None
        try:
            rm = self.registry.get(name)
        except KeyError as e:
            raise api.ApiError(404, str(e)) from None
        return {"name": name, "versions": [],
                "loaded_versions": self.registry.versions(name),
                "active": {}, "meta": {k: v for k, v in rm.meta.items()
                                       if isinstance(v, (str, int, float))}}

    def _require_manager(self) -> ModelManager:
        if self.manager is None:
            raise api.ApiError(
                503, "no lifecycle manager on this endpoint; start it with "
                     "a model store to enable load/unload/rollback")
        return self.manager

    # --- inference routes ----------------------------------------------------

    def _require_ensemble(self, alias: Optional[str] = None) -> Ensemble:
        if self.manager is not None:
            try:
                return self.manager.ensemble_for(alias)
            except LifecycleError as e:
                raise api.ApiError(404, str(e)) from None
        if alias is not None:
            raise api.ApiError(
                400, "per-request 'target' aliases need a lifecycle "
                     "manager on this endpoint")
        if self._ensemble is None:
            raise api.ApiError(503, "no ensemble deployed on this endpoint")
        return self._ensemble

    def _ensemble_logits(self, batch, alias: Optional[str],
                         ctx: RequestContext) -> Dict[str, np.ndarray]:
        """One forward's worth of per-member logits for this request's rows —
        coalesced with concurrent requests (of the same signature AND the
        same alias target) when the coalescer is on.  Admission is charged
        per ROW; a missed deadline surfaces as 504, a full queue as 429."""
        ens = self._require_ensemble(alias)
        rows = next(iter(batch.values())).shape[0]
        ticket = self._admit("infer", ctx, rows)
        try:
            if self.coalescer is not None:
                return self.coalescer.submit(batch, tag=alias, ctx=ctx)
            with self.device_lock:
                if ctx.expired():
                    raise DeadlineError(
                        "deadline exceeded waiting for the device lock")
                if self.manager is not None:
                    return self.manager.forward(batch, alias, [ctx])
                return ens.forward(batch)
        except DeadlineError as e:
            self.admission.deadline_miss(
                "infer", "coalesce" if self.coalescer is not None
                else "device_lock")
            raise api.ApiError(504, str(e)) from None
        except LifecycleError as e:
            raise api.ApiError(404, str(e)) from None
        except KeyError as e:
            raise api.ApiError(400, str(e)) from None
        except ValueError as e:
            raise api.ApiError(400, str(e)) from None
        finally:
            ticket.release()

    def _infer(self, req, ctx: RequestContext) -> Dict[str, Any]:
        alias = req.get("target")
        ens = self._require_ensemble(alias)
        batch = api.inputs_to_batch(req.get("inputs", {}))
        policy = req.get("policy", "soft_vote")
        logits = self._ensemble_logits(batch, alias, ctx)
        try:
            return ens.respond_from_logits(logits, policy=policy)
        except (KeyError, ValueError) as e:
            raise api.ApiError(400, str(e)) from None

    def _detect(self, req, ctx: RequestContext) -> Dict[str, Any]:
        alias = req.get("target")
        ens = self._require_ensemble(alias)
        batch = api.inputs_to_batch(req.get("inputs", {}))
        if "positive_class" not in req:
            raise api.ApiError(400, "'positive_class' is required")
        logits = self._ensemble_logits(batch, alias, ctx)
        out = ens.detect_from_logits(
            logits, positive_class=int(req["positive_class"]),
            threshold=float(req.get("threshold", 0.5)),
            policy=req.get("policy", "or"))
        resp = {f"model_{i}": v
                for i, v in enumerate(out["members"].values())}
        resp["ensemble"] = out["ensemble"]
        resp["policy"] = req.get("policy", "or")
        return resp

    def _generate(self, req, ctx: RequestContext):
        prompts = req.get("prompts")
        if not prompts or not isinstance(prompts, list):
            raise api.ApiError(400, "'prompts' must be a list of token lists")
        sampling = api.parse_sampling(req)
        alias = req.get("target")
        if req.get("stream"):
            return self._generate_stream(prompts, sampling, alias, ctx)
        cost = sum(len(p) for p in prompts if isinstance(p, list)) \
            + len(prompts) * sampling.max_new_tokens
        ticket = self._admit("generate", ctx, cost)
        try:
            if self.generation is not None and (self.generation.ready
                                                or alias is not None):
                res = self.generation.generate(prompts, sampling,
                                               alias=alias, ctx=ctx)
            elif self.engine is not None:
                if alias is not None:
                    raise api.ApiError(
                        400, "per-request 'target' aliases need a "
                             "generation service on this endpoint")
                with self.device_lock:
                    if ctx.expired():
                        self.admission.deadline_miss("generate",
                                                     "device_lock")
                        raise api.ApiError(
                            504, "deadline exceeded waiting for the "
                                 "device lock")
                    res = self.engine.generate(prompts, sampling=sampling)
            else:
                raise api.ApiError(503, "no generation engine deployed")
        except ShedError as e:
            raise self._shed_to_api(e) from None
        except GenerationError as e:
            raise api.ApiError(404, str(e)) from None
        except (ValueError, TypeError) as e:
            raise api.ApiError(400, str(e)) from None
        finally:
            ticket.release()
        if res.finish_reasons and all(r == "deadline"
                                      for r in res.finish_reasons):
            self.admission.deadline_miss("generate", "scheduler")
            raise api.ApiError(
                504, f"deadline exceeded before decode "
                     f"({ctx.trace_id or 'request'})")
        return {"outputs": res.tokens, "steps": res.steps,
                "prompt_lengths": res.prompt_lengths,
                "finish_reasons": res.finish_reasons}

    def _generate_stream(self, prompts, sampling, alias,
                         ctx: RequestContext) -> api.StreamingResponse:
        if self.generation is None or not (self.generation.ready
                                           or alias is not None):
            raise api.ApiError(
                503, "streaming needs the scheduler-backed generation "
                     "service (engine deployed, coalesce=True)")
        if len(prompts) != 1:
            raise api.ApiError(
                400, "streaming supports exactly one prompt per request")
        cost = (len(prompts[0]) if isinstance(prompts[0], list) else 1) \
            + sampling.max_new_tokens
        ticket = self._admit("generate", ctx, cost)
        try:
            # the ticket's budget hold lives as long as the stream: it is
            # released by the terminal event or by disconnect-cancellation
            stream = self.generation.stream(prompts[0], sampling,
                                            alias=alias, ctx=ctx,
                                            on_finish=ticket.release)
        except ShedError as e:
            ticket.release()
            raise self._shed_to_api(e) from None
        except GenerationError as e:
            ticket.release()
            raise api.ApiError(404, str(e)) from None
        except (ValueError, TypeError) as e:
            ticket.release()
            raise api.ApiError(400, str(e)) from None
        except BaseException:
            ticket.release()
            raise
        return api.StreamingResponse(stream.events(),
                                     on_disconnect=stream.cancel)


_REASONS = {200: "OK", 202: "Accepted", 400: "Bad Request", 404: "Not Found",
            409: "Conflict", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}

# request-plane headers the lean parser captures (already lowercase)
_PLANE_HEADERS = (b"x-flexserve-priority", b"x-flexserve-deadline-ms",
                  b"x-flexserve-client", b"x-request-id")


def make_handler(app: FlexServeApp):
    class Handler(socketserver.StreamRequestHandler):
        """Lean HTTP/1.1 keep-alive handler.

        The stdlib BaseHTTPRequestHandler parses headers through
        email.parser and writes responses in several syscalls — measurable
        per-request cost once the device work is coalesced away.  Serving
        needs exactly: request line, Content-Length, Connection; the
        response goes out as ONE write (which also avoids Nagle/delayed-ACK
        stalls when a coalesced batch releases many responses at once).
        """

        disable_nagle_algorithm = True
        timeout = 120

        def handle(self):
            try:
                while self._one_request():
                    pass
            except (ConnectionError, TimeoutError, OSError):
                pass                          # client went away

        def _one_request(self) -> bool:
            line = self.rfile.readline(65537)
            if not line or line in (b"\r\n", b"\n"):
                return False
            parts = line.split()
            if len(parts) < 2:
                return False
            method, path = parts[0].decode("latin-1"), \
                parts[1].decode("latin-1")
            length, keep = 0, True
            plane: Optional[Dict[str, str]] = None
            while True:
                h = self.rfile.readline(65537)
                if h in (b"\r\n", b"\n", b""):
                    break
                key, _, val = h.partition(b":")
                key = key.strip().lower()
                if key == b"content-length":
                    try:
                        length = int(val)
                    except ValueError:
                        self._reply(
                            400,
                            api.encode_response(api.error_body(api.ApiError(
                                400, "bad Content-Length"))),
                            False)
                        return False
                elif key == b"connection":
                    keep = b"close" not in val.lower()
                elif key in _PLANE_HEADERS:
                    if plane is None:
                        plane = {}
                    plane[key.decode("latin-1")] = \
                        val.strip().decode("latin-1")
            body = self.rfile.read(length) if length else b""
            extra = None
            try:
                status, payload = 200, app.handle(method, path, body, plane)
            except api.ApiError as e:
                status, extra = e.status, e.headers
                payload = api.error_body(e)
            except Exception as e:          # noqa: BLE001 — server boundary
                status = 500
                payload = api.error_body(
                    api.ApiError(500, f"{type(e).__name__}: {e}"))
            if isinstance(payload, api.StreamingResponse):
                return self._stream_reply(payload, keep)
            ctype = "application/json"
            if isinstance(payload, api.PlainTextResponse):
                status, ctype = payload.status, payload.content_type
                data = payload.text.encode("utf-8")
            elif isinstance(payload, api.JsonResponse):
                status = payload.status
                extra = {**payload.headers, **(extra or {})}
                data = api.encode_response(payload.payload)
            else:
                data = api.encode_response(payload)
            self._reply(status, data, keep, extra, ctype)
            return keep

        def _reply(self, status: int, data: bytes, keep: bool,
                   extra: Optional[Dict[str, str]] = None,
                   ctype: str = "application/json") -> None:
            lines = "".join(f"{k}: {v}\r\n" for k, v in (extra or {}).items())
            head = (f"HTTP/1.1 {status} {_REASONS.get(status, '')}\r\n"
                    f"Content-Type: {ctype}\r\n"
                    f"Content-Length: {len(data)}\r\n"
                    f"{lines}"
                    f"Connection: {'keep-alive' if keep else 'close'}\r\n"
                    f"\r\n").encode("latin-1")
            self.wfile.write(head + data)     # one syscall, one segment

        def _stream_reply(self, resp: api.StreamingResponse,
                          keep: bool) -> bool:
            """Write a token stream as chunked transfer encoding — one
            NDJSON event per chunk, flushed as it decodes, so the client
            sees the first token long before the stream finishes.  A
            failed write means the client went away: cancel the request
            (freeing its decode slot) and drop the connection."""
            lines = "".join(f"{k}: {v}\r\n"
                            for k, v in resp.headers.items())
            head = (f"HTTP/1.1 200 OK\r\n"
                    f"Content-Type: application/x-ndjson\r\n"
                    f"Transfer-Encoding: chunked\r\n"
                    f"{lines}"
                    f"Connection: {'keep-alive' if keep else 'close'}\r\n"
                    f"\r\n").encode("latin-1")
            try:
                self.wfile.write(head)
                for event in resp.events:
                    if app.faults is not None:
                        # "socket_drop": the connection dies mid-stream —
                        # same teardown path as a real failed write
                        app.faults.fire("socket_drop")
                    data = api.encode_response(event) + b"\n"
                    # chunk = size line + payload (wfile is unbuffered:
                    # one write, one segment — the flush per token)
                    self.wfile.write(b"%x\r\n%s\r\n" % (len(data), data))
                self.wfile.write(b"0\r\n\r\n")
                return keep
            except InjectedFault:
                resp.disconnect()             # cancel: free the decode slot
                try:
                    self.connection.close()
                except OSError:
                    pass
                return False
            except (ConnectionError, TimeoutError, OSError):
                resp.disconnect()             # cancel: free the decode slot
                return False

    return Handler


class _ThreadingServer(socketserver.ThreadingTCPServer):
    daemon_threads = True
    allow_reuse_address = True


class FlexServeServer:
    """Owns the listening socket; ``start()`` serves on a daemon thread."""

    def __init__(self, app: FlexServeApp, host: str = "127.0.0.1",
                 port: int = 0):
        self.app = app
        self.httpd = _ThreadingServer((host, port), make_handler(app))

    @property
    def address(self):
        return self.httpd.server_address

    def start(self, wait_ready: bool = True,
              timeout: float = 10.0) -> "FlexServeServer":
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        if wait_ready:
            self.wait_ready(timeout)
        return self

    def wait_ready(self, timeout: float = 10.0) -> bool:
        """Poll GET /healthz over real HTTP until the endpoint reports
        ready (the same probe an orchestrator would use); returns whether
        readiness was observed within the timeout."""
        from repro.serving.client import FlexServeClient
        host, port = self.address
        client = FlexServeClient(host, port, timeout=max(timeout, 1.0))
        deadline = time.monotonic() + timeout
        try:
            while time.monotonic() < deadline:
                try:
                    client.healthz()
                    return True
                except (RuntimeError, OSError):
                    time.sleep(0.02)
        finally:
            client.close()
        return False

    def stop(self) -> None:
        self.app._closing = True
        self.httpd.shutdown()
        self.httpd.server_close()
        self.app.close()
