"""Streaming generation subsystem: the request-lifecycle layer between the
REST front-end and the continuous-batching scheduler.

``GenerationService`` owns everything that happens to a generate request
after the HTTP handler has parsed it:

  * **Token streaming** — ``stream()`` admits one prompt into a decode
    slot and returns a ``GenerationStream`` whose ``events()`` iterator
    yields one JSON-able event per decoded token as it lands (the HTTP
    layer writes each as one chunk), closing with an end-of-stream summary
    (token count, finish reason, TTFT, total latency).  Non-streaming
    ``generate()`` keeps the blocking all-at-once path.

  * **Per-request sampling** — every request carries its own
    ``SamplingParams``; slots sharing a decode batch sample independently
    ON DEVICE through the fused decode step (see repro.core.sampling):
    per tick only the sampled token ids cross to host, and the first
    token comes from the scheduler's BATCHED bucketed prefill (queued
    same-signature admissions share one forward).  Per-scheduler decode
    breakdown (host/device ms, transfer bytes, prefill batching) is on
    ``stats()`` under ``"decode"``.

  * **Versioned engines** — the service maps version ALIASES ("stable",
    "canary", ...) to engine entries, mirroring the lifecycle manager's
    ensemble aliases.  ``install()`` hot-swaps an alias to a new engine:
    new requests land on the new engine's scheduler immediately, in-flight
    streams DRAIN on the old engine (nothing is truncated), and only then
    is the old scheduler closed.  The ``ModelManager`` drives this from
    store-backed versions (load_engine / rollback_engine).

  * **Cancellation** — a client that disconnects mid-stream has its
    request cancelled and its decode slot freed at the next scheduler
    tick; cancellations, TTFT, and inter-token latency are all on
    /metrics.

  * **Backpressure** — each stream's event queue is BOUNDED.  When a
    stalled consumer lets it fill, the stream's decode slot is PAUSED
    (preempted — the slot goes to other traffic) instead of buffering
    tokens unboundedly; when the consumer drains the queue, the missed
    tokens are replayed from the request's output record and the request
    resumes via recompute (re-prefill of prompt + output so far).  A
    consumer that never returns is handled by the existing
    disconnect-cancellation path, which frees the parked request too.

The token sinks run on each scheduler's driver thread and only ever
enqueue (never block) into per-stream queues — a slow or dead client
never stalls decoding for the other slots.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

from repro.core.engine import GenerationResult, InferenceEngine
from repro.core.faults import FaultInjector
from repro.core.sampling import SamplingParams
from repro.core.scheduler import (Request, SchedulerBusy, SchedulerService,
                                  ZERO_PAGER_STATS, ZERO_SPECULATION_STATS)
from repro.core.telemetry import BYTES_BUCKETS, Histogram
from repro.serving.admission import RequestContext, ShedError
from repro.serving.replica import (CORDONED, READY, ReplicaPool,
                                   ZERO_REPLICA_STATS)

# HTTP status a finished stream's trace records, by finish reason
_TRACE_STATUS = {"deadline": 504, "error": 500, "cancelled": 499}


class GenerationError(RuntimeError):
    """Generation-plane failure (no engine, unknown alias)."""


class _EngineEntry:
    """One versioned engine serving one alias: its own scheduler service
    (or a :class:`~repro.serving.replica.ReplicaPool` duck-typing it)."""

    __slots__ = ("name", "version", "service", "installed_at")

    def __init__(self, name: str, version: int, service: SchedulerService):
        self.name = name
        self.version = version
        self.service = service
        self.installed_at = time.time()

    @property
    def label(self) -> str:
        return f"{self.name}@v{self.version}"


class _BoundedEvents:
    """Per-stream event transport with a hard bound.  Token puts FAIL when
    full (the sink then pauses the slot — backpressure, not buffering);
    terminal puts always land so a stream can always be closed out."""

    class Empty(Exception):
        pass

    def __init__(self, bound: int):
        self._dq: collections.deque = collections.deque()
        self._bound = max(1, bound)
        self._cond = threading.Condition()
        self.high_water = 0

    def put(self, ev: Optional[Dict[str, Any]], *,
            force: bool = False) -> bool:
        with self._cond:
            if not force and len(self._dq) >= self._bound:
                return False
            self._dq.append(ev)
            self.high_water = max(self.high_water, len(self._dq))
            self._cond.notify()
            return True

    def get(self, timeout: float) -> Optional[Dict[str, Any]]:
        with self._cond:
            if not self._dq:
                self._cond.wait(timeout)
            if not self._dq:
                raise self.Empty
            return self._dq.popleft()

    def depth(self) -> int:
        with self._cond:
            return len(self._dq)


class GenerationStream:
    """Handle on one in-flight streaming request.

    ``events()`` yields dict events in order:
        {"event": "token", "token": t, "index": i}          (per token)
        {"event": "done", "tokens": [...], "finish_reason": ...,
         "token_count": n, "prompt_length": ..., "ttft_ms": ...,
         "total_ms": ..., "engine": "name@vN"}              (terminal)
    or a terminal {"event": "error", "error": ...} if the engine failed.
    ``cancel()`` abandons the request and frees its decode slot.

    The event queue holds at most ``max_buffered`` token events.  A
    consumer that stalls past that pauses the request's decode slot (the
    sink never blocks and never buffers more); when the consumer comes
    back, ``events()`` replays anything it missed straight from the
    request's output record and resumes the request.
    """

    def __init__(self, service: "GenerationService", entry: _EngineEntry,
                 sampling: SamplingParams, *,
                 ctx: Optional[RequestContext] = None,
                 max_buffered: int = 32,
                 on_finish: Optional[Callable[[], Any]] = None):
        self._service = service
        self._entry = entry
        self._sampling = sampling
        self.ctx = ctx
        self._queue = _BoundedEvents(max_buffered)
        self._on_finish = on_finish
        self._finish_lock = threading.Lock()
        self.request: Optional[Request] = None        # set right after submit

    # --- sink: runs on the scheduler driver thread; must never block ---------

    def _sink(self, req: Request, token: Optional[int], done: bool) -> None:
        tr = req.trace
        if token is not None:
            ev = {"event": "token", "token": token,
                  "index": len(req.output) - 1}
            ok = self._queue.put(ev)
            if tr is not None:
                tr.bump("stream_events")
            if not ok and self._entry.service.retiring:
                # engine swap draining: backpressure yields to the
                # zero-truncation guarantee — growth is bounded by the
                # request's remaining token budget
                self._queue.put(ev, force=True)
                if tr is not None:
                    tr.bump("swap_drain_forced")
            elif not ok and not done:
                # consumer stalled: preempt the slot rather than buffer.
                # The dropped token stays in req.output and is replayed by
                # events() before the resume.  Setting the flag directly is
                # safe — the sink runs ON the driver thread.
                req.paused = True
                if tr is not None:
                    tr.bump("stream_stalls")
                self._service._stream_paused()
        if done:
            self._queue.put(self._terminal_event(req), force=True)
            self._queue.put(None, force=True)         # end-of-stream marker
            self._finish_once()
            self._service._finished(req)

    def _finish_once(self) -> None:
        # a disconnect (handler thread) can race the terminal sink event
        # (driver thread); the swap under a lock guarantees one caller
        with self._finish_lock:
            cb, self._on_finish = self._on_finish, None
        if cb is not None:
            cb()
        # a STREAM's trace is sealed here, not by the HTTP route (which
        # returns before the stream body finishes).  Trace.finish is
        # idempotent, so the disconnect/terminal race records one outcome.
        req = self.request
        if req is not None and req.done:
            tr = req.trace
            if tr is not None:
                tr.finish(
                    status=_TRACE_STATUS.get(req.finish_reason, 200),
                    finish_reason=req.finish_reason,
                    error=(f"{type(req.error).__name__}: {req.error}"
                           if req.error is not None else None))

    def _terminal_event(self, req: Request) -> Dict[str, Any]:
        if req.finish_reason == "error":
            return {"event": "error",
                    "error": f"{type(req.error).__name__}: {req.error}"
                             if req.error is not None else "engine failure"}
        ev = {"event": "done", "tokens": list(req.output),
              "finish_reason": req.finish_reason,
              "token_count": len(req.output),
              "prompt_length": len(req.prompt),
              "total_ms": 1e3 * (req.latency_s or 0.0),
              "engine": self._entry.label,
              "sampling": self._sampling.describe(),
              # speculative-decoding acceptance summary: zeros when the
              # serving engine is non-speculative or the request opted out
              "speculation": {
                  "proposed": req.spec_proposed,
                  "accepted": req.spec_accepted,
                  "acceptance_rate": (req.spec_accepted / req.spec_proposed
                                      if req.spec_proposed else 0.0)}}
        if req.ttft_s is not None:
            ev["ttft_ms"] = 1e3 * req.ttft_s
        if req.pause_count:
            ev["pauses"] = req.pause_count
        if self.ctx is not None and self.ctx.trace_id:
            ev["trace_id"] = self.ctx.trace_id
        return ev

    # --- consumer side --------------------------------------------------------

    _POLL_S = 0.02

    def events(self, timeout: Optional[float] = 120.0
               ) -> Iterator[Dict[str, Any]]:
        """Yield events until the terminal one (inclusive).  ``timeout``
        bounds the wait for EACH event, not the whole stream.  Tokens the
        bounded queue dropped during a pause are replayed (in order, by
        index) from the request's output record before the request is
        resumed, so the consumer sees every token exactly once."""
        next_idx = 0
        waited = 0.0
        while True:
            poll = (self._POLL_S if timeout is None
                    else min(self._POLL_S, max(timeout - waited, 0.001)))
            t0 = time.perf_counter()
            try:
                ev = self._queue.get(timeout=poll)
            except _BoundedEvents.Empty:
                req = self.request
                if (req is not None and req.paused and not req.done):
                    # stalled consumer came back: hand it what the queue
                    # dropped (req.output only ever grows; the slice is
                    # safe to read), then put the request back to work
                    for j in range(next_idx, len(req.output)):
                        yield {"event": "token", "token": req.output[j],
                               "index": j, "replayed": True}
                        next_idx = j + 1
                    self._entry.service.resume(req)
                    waited = 0.0
                    continue
                waited += time.perf_counter() - t0
                if timeout is not None and waited >= timeout:
                    self.cancel()
                    yield {"event": "error",
                           "error": f"no token within {timeout}s"}
                    return
                continue
            waited = 0.0
            if ev is None:
                return
            if ev.get("event") == "token":
                idx = ev["index"]
                if idx < next_idx:
                    continue              # duplicate of a replayed token
                while next_idx < idx:     # gap: dropped while queue full
                    yield {"event": "token",
                           "token": self.request.output[next_idx],
                           "index": next_idx, "replayed": True}
                    next_idx += 1
                next_idx = idx + 1
                yield ev
            else:
                if ev.get("event") == "done":
                    toks = ev.get("tokens") or []
                    while next_idx < len(toks):   # gap before the terminal
                        yield {"event": "token", "token": toks[next_idx],
                               "index": next_idx, "replayed": True}
                        next_idx += 1
                yield ev

    def queue_depth(self) -> int:
        return self._queue.depth()

    @property
    def queue_high_water(self) -> int:
        return self._queue.high_water

    def _reassign(self, new_req: Request) -> None:
        """Replica failover moved the request: subsequent replay/resume/
        cancel must target the NEW request.  Safe to swap mid-iteration —
        the new request's output starts as a superset snapshot of the old
        one's, so index-based replay stays monotonic."""
        self.request = new_req

    def cancel(self) -> bool:
        """Abandon the stream (client went away); frees the decode slot —
        including a slot-less parked (paused) request."""
        self._finish_once()
        if self.request is None:
            return False
        return self._entry.service.cancel(self.request)


class GenerationService:
    """Versioned, streaming generate front-end (see module docstring).

    Constructed either around a static ``engine`` (installed as
    ``engine@v0`` under the default alias) or empty, with engines
    installed later by the lifecycle manager.
    """

    def __init__(self, engine: Optional[InferenceEngine] = None, *,
                 num_slots: int = 4, default_alias: str = "stable",
                 drain_timeout_s: float = 30.0,
                 max_pending: Optional[int] = None,
                 max_stream_buffer: int = 32,
                 client_weights: Optional[Dict[str, float]] = None,
                 num_replicas: int = 1,
                 faults: Optional[FaultInjector] = None,
                 replica_options: Optional[Dict[str, Any]] = None):
        self.num_slots = num_slots
        self.default_alias = default_alias
        self.drain_timeout_s = drain_timeout_s
        # per-client weighted fair dequeue inside every engine's scheduler
        self.client_weights = client_weights
        # replica pool: with num_replicas > 1 every installed engine fans
        # out into N health-checked SchedulerService replicas behind one
        # entry (engine swaps swap the whole pool); replica_options tunes
        # the pool's health monitor / failover knobs
        self.num_replicas = max(1, num_replicas)
        self.faults = faults
        self.replica_options = dict(replica_options or {})
        # backstop bound on each engine's pending deque; the app-level
        # AdmissionController sheds earlier (and with better hints), this
        # keeps a directly-driven service bounded too
        self.max_pending = (max_pending if max_pending is not None
                            else max(32, 8 * num_slots))
        self.max_stream_buffer = max_stream_buffer
        self._lock = threading.Lock()
        self._aliases: Dict[str, _EngineEntry] = {}
        self._stats_lock = threading.Lock()
        self._streams = {"started": 0, "completed": 0, "cancelled": 0,
                         "failed": 0, "deadline": 0, "paused": 0}
        self._swaps = 0
        self._closed = False
        if engine is not None:
            self.install("engine", 0, engine)

    # --- engine lifecycle -----------------------------------------------------

    def install(self, name: str, version: int, engine: InferenceEngine, *,
                alias: Optional[str] = None,
                num_slots: Optional[int] = None,
                warm: bool = False) -> Dict[str, Any]:
        """Serve ``engine`` as ``name@vversion`` under ``alias``.

        The swap is atomic for admission: requests submitted after this
        returns (and any racing submit that wins the pointer swap) land on
        the NEW engine.  Requests already admitted keep decoding on the
        old engine until they finish — the old scheduler is drained, then
        closed, so no in-flight stream is truncated by a swap.  ``warm``
        pre-compiles the decode data path (fused step, batched-prefill
        buckets, slot scatter) BEFORE the alias flips, so the first live
        streams never pay compile latency.

        With ``num_replicas > 1`` the engine fans out into a full
        :class:`ReplicaPool` (one scheduler per replica over the SHARED
        engine).  A failure while building the pool — e.g. an injected
        ``engine_install`` fault — tears the partial pool down and
        propagates BEFORE the alias flips, so no request ever observes a
        half-installed version."""
        if self.num_replicas > 1:
            service = ReplicaPool(engine, self.num_replicas,
                                  num_slots=num_slots or self.num_slots,
                                  max_pending=self.max_pending,
                                  client_weights=self.client_weights,
                                  faults=self.faults, warm=warm,
                                  **self.replica_options)
            warm_s = service.warm_s
        else:
            service = SchedulerService(
                engine, num_slots=num_slots or self.num_slots,
                max_pending=self.max_pending,
                client_weights=self.client_weights,
                faults=self.faults)
            warm_s = service.warm() if warm else 0.0
        entry = _EngineEntry(name, version, service)
        with self._lock:
            if self._closed:
                service.close()
                raise GenerationError("generation service is closed")
            alias = alias or self.default_alias
            old = self._aliases.get(alias)
            self._aliases[alias] = entry
            # alias re-pointing (promote/demote) lets several aliases
            # share one entry: only retire the displaced entry once no
            # alias references it anymore
            still = any(e is old for e in self._aliases.values())
        drained, drain_s = True, 0.0
        if old is not None and not still:
            drained, drain_s = self._retire(old)
        with self._stats_lock:
            self._swaps += 1
        return {"alias": alias, "engine": entry.label,
                "previous_engine": old.label if old is not None else None,
                "drained": drained, "drain_ms": 1e3 * drain_s,
                "warm_ms": 1e3 * warm_s}

    def _retire(self, old: _EngineEntry) -> "tuple[bool, float]":
        # refuse-new FIRST: a submit racing the swap either landed
        # before this (drain waits for it) or raises and is retried
        # on the alias's new entry — no stream is ever stranded in a
        # closing scheduler
        old.service.begin_retire()
        t0 = time.perf_counter()
        drained = old.service.drain(self.drain_timeout_s)
        drain_s = time.perf_counter() - t0
        old.service.close()
        return drained, drain_s

    def repoint(self, from_alias: str, to_alias: str) -> Dict[str, Any]:
        """Point ``to_alias`` at ``from_alias``'s engine entry — the
        canary-promotion primitive (``repoint("canary", "stable")`` makes
        the canary's engine the stable one with NO reload and NO warmup:
        both aliases share the live entry, scheduler and all).  The entry
        ``to_alias`` displaced drains and closes only if no other alias
        still references it.  Demotion is the same call reversed."""
        with self._lock:
            if self._closed:
                raise GenerationError("generation service is closed")
            try:
                src = self._aliases[from_alias]
            except KeyError:
                raise GenerationError(
                    f"no generation engine under alias {from_alias!r}; "
                    f"available: {sorted(self._aliases)}") from None
            old = self._aliases.get(to_alias)
            if old is src:
                return {"alias": to_alias, "engine": src.label,
                        "previous_engine": src.label, "changed": False}
            self._aliases[to_alias] = src
            still = any(e is old for e in self._aliases.values())
        drained, drain_s = True, 0.0
        if old is not None and not still:
            drained, drain_s = self._retire(old)
        with self._stats_lock:
            self._swaps += 1
        return {"alias": to_alias, "engine": src.label,
                "previous_engine": old.label if old is not None else None,
                "changed": True, "drained": drained,
                "drain_ms": 1e3 * drain_s}

    @property
    def ready(self) -> bool:
        with self._lock:
            return self.default_alias in self._aliases

    def aliases(self) -> List[str]:
        with self._lock:
            return sorted(self._aliases)

    def entry_for(self, alias: Optional[str] = None) -> _EngineEntry:
        alias = alias or self.default_alias
        with self._lock:
            try:
                return self._aliases[alias]
            except KeyError:
                raise GenerationError(
                    f"no generation engine under alias {alias!r}; "
                    f"available: {sorted(self._aliases)}") from None

    def engine_for(self, alias: Optional[str] = None) -> InferenceEngine:
        return self.entry_for(alias).service.engine

    # --- request lifecycle ----------------------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]],
                 sampling: Optional[SamplingParams] = None, *,
                 alias: Optional[str] = None,
                 ctx: Optional[RequestContext] = None,
                 timeout: Optional[float] = None) -> GenerationResult:
        """Blocking all-at-once generation (the legacy response shape).
        ``ctx`` carries priority + deadline into the scheduler's pending
        deques; a full deque surfaces as ShedError (429 upstream)."""
        sampling = sampling or SamplingParams()
        while True:
            entry = self.entry_for(alias)
            self._annotate_version(ctx, entry, alias)
            try:
                return entry.service.submit_and_wait(
                    prompts, sampling=sampling, ctx=ctx, timeout=timeout)
            except GenerationError:
                raise
            except SchedulerBusy as e:
                raise ShedError(str(e)) from None
            except RuntimeError:
                # raced an engine swap into the retiring old service: the
                # alias already points at the replacement — retry there.
                # Each retry requires ANOTHER swap to have moved the
                # pointer, so this terminates; an unmoved pointer means a
                # real failure
                if entry is self.entry_for(alias):
                    raise

    def stream(self, prompt: Sequence[int],
               sampling: Optional[SamplingParams] = None, *,
               alias: Optional[str] = None,
               ctx: Optional[RequestContext] = None,
               max_buffered: Optional[int] = None,
               on_finish: Optional[Callable[[], Any]] = None
               ) -> GenerationStream:
        """Admit one prompt and return the stream handle immediately;
        tokens arrive on the handle as the scheduler decodes them.
        ``max_buffered`` bounds the stream's event queue (backpressure —
        see GenerationStream); ``on_finish`` runs exactly once when the
        stream reaches a terminal event or is cancelled."""
        sampling = sampling or SamplingParams()
        while True:
            entry = self.entry_for(alias)
            self._annotate_version(ctx, entry, alias)
            stream = GenerationStream(
                self, entry, sampling, ctx=ctx,
                max_buffered=max_buffered or self.max_stream_buffer,
                on_finish=on_finish)
            try:
                stream.request = entry.service.submit_request(
                    prompt, sampling=sampling, sink=stream._sink, ctx=ctx,
                    on_reassign=stream._reassign)
                break
            except GenerationError:
                raise
            except SchedulerBusy as e:
                stream._finish_once()
                raise ShedError(str(e)) from None
            except RuntimeError:
                # raced an engine swap into the retiring old service: the
                # alias already points at the replacement — admit there.
                # Terminates because each retry needs another swap to have
                # moved the pointer; an unmoved pointer is a real failure
                if entry is self.entry_for(alias):
                    raise
        with self._stats_lock:
            self._streams["started"] += 1
        return stream

    def _annotate_version(self, ctx: Optional[RequestContext],
                          entry: _EngineEntry,
                          alias: Optional[str]) -> None:
        """Stamp the serving engine's identity on the request trace so
        the SLI/usage aggregators can attribute it per version (and the
        SLO controller can evaluate the alias's traffic)."""
        tr = getattr(ctx, "trace", None)
        if tr is not None and hasattr(tr, "annotate"):
            tr.annotate("version", entry.label)
            tr.annotate("alias", alias or self.default_alias)

    def _finished(self, req: Request) -> None:
        key = ("cancelled" if req.finish_reason == "cancelled" else
               "failed" if req.finish_reason == "error" else
               "deadline" if req.finish_reason == "deadline" else
               "completed")
        with self._stats_lock:
            self._streams[key] += 1

    def _stream_paused(self) -> None:
        with self._stats_lock:
            self._streams["paused"] += 1

    # --- observability / teardown ---------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            entries = dict(self._aliases)
        engines = {a: {"engine": e.label, **e.service.stats()}
                   for a, e in entries.items()}
        with self._stats_lock:
            out: Dict[str, Any] = {"streams": dict(self._streams),
                                   "engine_swaps": self._swaps}
        # the default alias's scheduler stats at top level keep the
        # /metrics "generate" section shape stable for dashboards — zeroed
        # before the first engine load so scrapers never hit missing keys
        zero_ms = Histogram().snapshot()
        zero_bytes = Histogram(BYTES_BUCKETS).snapshot()
        out.update({"steps": 0, "active_slots": 0, "pending": 0,
                    "pending_high_water": 0,
                    "max_pending": self.max_pending,
                    "parked": 0, "pauses": 0,
                    "num_slots": self.num_slots, "completed": 0,
                    "cancelled": 0, "deadline_missed": 0,
                    "request_latency_p50_ms": 0.0,
                    "request_latency_p95_ms": 0.0,
                    "ttft_p50_ms": 0.0, "ttft_p95_ms": 0.0,
                    "inter_token_p50_ms": 0.0, "inter_token_p95_ms": 0.0,
                    "request_latency_ms_hist": zero_ms,
                    "ttft_ms_hist": zero_ms,
                    "inter_token_ms_hist": zero_ms,
                    "queue_wait_ms_hist": zero_ms,
                    "decode": {"device_sampling": True, "ticks": 0,
                               "host_ms_p50": 0.0, "host_ms_p95": 0.0,
                               "device_ms_p50": 0.0, "device_ms_p95": 0.0,
                               "prefill_ms_p50": 0.0,
                               "transfer_bytes_per_tick_p50": 0,
                               "transfer_bytes_total": 0,
                               "prefill_transfer_bytes_total": 0,
                               "prefill_forwards": 0,
                               "prefill_requests": 0,
                               "prefill_s_total": 0.0,
                               "device_ms_total": 0.0,
                               "host_ms_total": 0.0,
                               "decode_tokens_total": 0,
                               "prefill_tokens_total": 0,
                               "compiled_steps": None,
                               "host_ms_hist": zero_ms,
                               "device_ms_hist": zero_ms,
                               "prefill_ms_hist": zero_ms,
                               "transfer_bytes_hist": zero_bytes},
                    # paged-KV engines overwrite the zeroed KVPager schema
                    # (page utilization, prefix hit rate, fast resumes)
                    "pager": dict(ZERO_PAGER_STATS),
                    # speculative engines overwrite the zeroed schema
                    # (acceptance EMA, window histogram, draft/verify ms)
                    "speculation": dict(ZERO_SPECULATION_STATS),
                    # replica pools overwrite the zeroed pool schema
                    # (lifecycle states, failovers, restarts)
                    "replicas": dict(ZERO_REPLICA_STATS)})
        default = engines.get(self.default_alias)
        if default is not None:
            out.update({k: v for k, v in default.items() if k != "engine"})
        out["engines"] = engines
        return out

    # --- replica pool surface ---------------------------------------------------

    def pool_for(self, alias: Optional[str] = None
                 ) -> Optional[ReplicaPool]:
        """The alias's replica pool, or ``None`` in single-service mode
        (or before any engine is installed)."""
        try:
            entry = self.entry_for(alias)
        except GenerationError:
            return None
        return entry.service if isinstance(entry.service, ReplicaPool) \
            else None

    def replica_summary(self, alias: Optional[str] = None
                        ) -> Dict[str, Any]:
        """Pool health summary for /healthz and /v1/replicas.  In
        single-service mode the one implicit replica is reported (ready
        iff its driver thread is alive), so readiness aggregation works
        either way."""
        try:
            entry = self.entry_for(alias)
        except GenerationError:
            return dict(ZERO_REPLICA_STATS)
        svc = entry.service
        if isinstance(svc, ReplicaPool):
            return svc.summary()
        out = dict(ZERO_REPLICA_STATS)
        alive = bool(getattr(svc, "alive", True))
        out.update({
            "count": 1,
            "ready": 1 if alive else 0,
            "per_replica": {"0": {
                "id": 0, "state": READY if alive else CORDONED,
                "manual": False, "cordoned_reason": None, "restarts": 0,
                "steps": svc.scheduler.steps,
                "active": svc.scheduler.active,
                "pending": svc.scheduler.pending,
                "driver_errors": svc.driver_errors,
                "consecutive_errors": svc.consecutive_errors,
                "last_tick_ms": svc.last_tick_s * 1e3,
                "alive": alive,
            }}})
        if not alive:
            out["cordoned"] = 1
            out["cordoned_ids"] = [0]
        return out

    def close(self) -> None:
        with self._lock:
            self._closed = True
            entries = list(self._aliases.values())
            self._aliases.clear()
        seen: set = set()
        for e in entries:              # aliases may share one entry
            if id(e) not in seen:
                seen.add(id(e))
                e.service.close()
