"""Streaming generation subsystem: the request-lifecycle layer between the
REST front-end and the continuous-batching scheduler.

``GenerationService`` owns everything that happens to a generate request
after the HTTP handler has parsed it:

  * **Token streaming** — ``stream()`` admits one prompt into a decode
    slot and returns a ``GenerationStream`` whose ``events()`` iterator
    yields one JSON-able event per decoded token as it lands (the HTTP
    layer writes each as one chunk), closing with an end-of-stream summary
    (token count, finish reason, TTFT, total latency).  Non-streaming
    ``generate()`` keeps the blocking all-at-once path.

  * **Per-request sampling** — every request carries its own
    ``SamplingParams``; slots sharing a decode batch sample independently
    (see repro.core.sampling).

  * **Versioned engines** — the service maps version ALIASES ("stable",
    "canary", ...) to engine entries, mirroring the lifecycle manager's
    ensemble aliases.  ``install()`` hot-swaps an alias to a new engine:
    new requests land on the new engine's scheduler immediately, in-flight
    streams DRAIN on the old engine (nothing is truncated), and only then
    is the old scheduler closed.  The ``ModelManager`` drives this from
    store-backed versions (load_engine / rollback_engine).

  * **Cancellation** — a client that disconnects mid-stream has its
    request cancelled and its decode slot freed at the next scheduler
    tick; cancellations, TTFT, and inter-token latency are all on
    /metrics.

The token sinks run on each scheduler's driver thread and only ever
enqueue into per-stream queues — a slow or dead client never stalls
decoding for the other slots.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence

from repro.core.engine import GenerationResult, InferenceEngine
from repro.core.sampling import SamplingParams
from repro.core.scheduler import Request, SchedulerService


class GenerationError(RuntimeError):
    """Generation-plane failure (no engine, unknown alias)."""


class _EngineEntry:
    """One versioned engine serving one alias: its own scheduler service."""

    __slots__ = ("name", "version", "service", "installed_at")

    def __init__(self, name: str, version: int, service: SchedulerService):
        self.name = name
        self.version = version
        self.service = service
        self.installed_at = time.time()

    @property
    def label(self) -> str:
        return f"{self.name}@v{self.version}"


class GenerationStream:
    """Handle on one in-flight streaming request.

    ``events()`` yields dict events in order:
        {"event": "token", "token": t, "index": i}          (per token)
        {"event": "done", "tokens": [...], "finish_reason": ...,
         "token_count": n, "prompt_length": ..., "ttft_ms": ...,
         "total_ms": ..., "engine": "name@vN"}              (terminal)
    or a terminal {"event": "error", "error": ...} if the engine failed.
    ``cancel()`` abandons the request and frees its decode slot.
    """

    def __init__(self, service: "GenerationService", entry: _EngineEntry,
                 sampling: SamplingParams):
        self._service = service
        self._entry = entry
        self._sampling = sampling
        self._queue: "queue.Queue[Optional[Dict[str, Any]]]" = queue.Queue()
        self.request: Optional[Request] = None        # set right after submit

    # --- sink: runs on the scheduler driver thread; must never block ---------

    def _sink(self, req: Request, token: Optional[int], done: bool) -> None:
        if token is not None:
            self._queue.put({"event": "token", "token": token,
                             "index": len(req.output) - 1})
        if done:
            self._queue.put(self._terminal_event(req))
            self._queue.put(None)                     # end-of-stream marker
            self._service._finished(req)

    def _terminal_event(self, req: Request) -> Dict[str, Any]:
        if req.finish_reason == "error":
            return {"event": "error",
                    "error": f"{type(req.error).__name__}: {req.error}"
                             if req.error is not None else "engine failure"}
        ev = {"event": "done", "tokens": list(req.output),
              "finish_reason": req.finish_reason,
              "token_count": len(req.output),
              "prompt_length": len(req.prompt),
              "total_ms": 1e3 * (req.latency_s or 0.0),
              "engine": self._entry.label,
              "sampling": self._sampling.describe()}
        if req.ttft_s is not None:
            ev["ttft_ms"] = 1e3 * req.ttft_s
        return ev

    # --- consumer side --------------------------------------------------------

    def events(self, timeout: Optional[float] = 120.0
               ) -> Iterator[Dict[str, Any]]:
        """Yield events until the terminal one (inclusive).  ``timeout``
        bounds the wait for EACH event, not the whole stream."""
        while True:
            try:
                ev = self._queue.get(timeout=timeout)
            except queue.Empty:
                self.cancel()
                yield {"event": "error",
                       "error": f"no token within {timeout}s"}
                return
            if ev is None:
                return
            yield ev

    def cancel(self) -> bool:
        """Abandon the stream (client went away); frees the decode slot."""
        if self.request is None:
            return False
        return self._entry.service.cancel(self.request)


class GenerationService:
    """Versioned, streaming generate front-end (see module docstring).

    Constructed either around a static ``engine`` (installed as
    ``engine@v0`` under the default alias) or empty, with engines
    installed later by the lifecycle manager.
    """

    def __init__(self, engine: Optional[InferenceEngine] = None, *,
                 num_slots: int = 4, default_alias: str = "stable",
                 drain_timeout_s: float = 30.0):
        self.num_slots = num_slots
        self.default_alias = default_alias
        self.drain_timeout_s = drain_timeout_s
        self._lock = threading.Lock()
        self._aliases: Dict[str, _EngineEntry] = {}
        self._stats_lock = threading.Lock()
        self._streams = {"started": 0, "completed": 0, "cancelled": 0,
                         "failed": 0}
        self._swaps = 0
        self._closed = False
        if engine is not None:
            self.install("engine", 0, engine)

    # --- engine lifecycle -----------------------------------------------------

    def install(self, name: str, version: int, engine: InferenceEngine, *,
                alias: Optional[str] = None,
                num_slots: Optional[int] = None) -> Dict[str, Any]:
        """Serve ``engine`` as ``name@vversion`` under ``alias``.

        The swap is atomic for admission: requests submitted after this
        returns (and any racing submit that wins the pointer swap) land on
        the NEW engine.  Requests already admitted keep decoding on the
        old engine until they finish — the old scheduler is drained, then
        closed, so no in-flight stream is truncated by a swap."""
        service = SchedulerService(engine,
                                   num_slots=num_slots or self.num_slots)
        entry = _EngineEntry(name, version, service)
        with self._lock:
            if self._closed:
                service.close()
                raise GenerationError("generation service is closed")
            alias = alias or self.default_alias
            old = self._aliases.get(alias)
            self._aliases[alias] = entry
        drained, drain_s = True, 0.0
        if old is not None:
            # refuse-new FIRST: a submit racing the swap either landed
            # before this (drain waits for it) or raises and is retried
            # on the alias's new entry — no stream is ever stranded in a
            # closing scheduler
            old.service.begin_retire()
            t0 = time.perf_counter()
            drained = old.service.drain(self.drain_timeout_s)
            drain_s = time.perf_counter() - t0
            old.service.close()
        with self._stats_lock:
            self._swaps += 1
        return {"alias": alias, "engine": entry.label,
                "previous_engine": old.label if old is not None else None,
                "drained": drained, "drain_ms": 1e3 * drain_s}

    @property
    def ready(self) -> bool:
        with self._lock:
            return self.default_alias in self._aliases

    def aliases(self) -> List[str]:
        with self._lock:
            return sorted(self._aliases)

    def entry_for(self, alias: Optional[str] = None) -> _EngineEntry:
        alias = alias or self.default_alias
        with self._lock:
            try:
                return self._aliases[alias]
            except KeyError:
                raise GenerationError(
                    f"no generation engine under alias {alias!r}; "
                    f"available: {sorted(self._aliases)}") from None

    def engine_for(self, alias: Optional[str] = None) -> InferenceEngine:
        return self.entry_for(alias).service.engine

    # --- request lifecycle ----------------------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]],
                 sampling: Optional[SamplingParams] = None, *,
                 alias: Optional[str] = None,
                 timeout: Optional[float] = None) -> GenerationResult:
        """Blocking all-at-once generation (the legacy response shape)."""
        sampling = sampling or SamplingParams()
        while True:
            entry = self.entry_for(alias)
            try:
                return entry.service.submit_and_wait(
                    prompts, sampling=sampling, timeout=timeout)
            except GenerationError:
                raise
            except RuntimeError:
                # raced an engine swap into the retiring old service: the
                # alias already points at the replacement — retry there.
                # Each retry requires ANOTHER swap to have moved the
                # pointer, so this terminates; an unmoved pointer means a
                # real failure
                if entry is self.entry_for(alias):
                    raise

    def stream(self, prompt: Sequence[int],
               sampling: Optional[SamplingParams] = None, *,
               alias: Optional[str] = None) -> GenerationStream:
        """Admit one prompt and return the stream handle immediately;
        tokens arrive on the handle as the scheduler decodes them."""
        sampling = sampling or SamplingParams()
        while True:
            entry = self.entry_for(alias)
            stream = GenerationStream(self, entry, sampling)
            try:
                stream.request = entry.service.submit_request(
                    prompt, sampling=sampling, sink=stream._sink)
                break
            except GenerationError:
                raise
            except RuntimeError:
                # raced an engine swap into the retiring old service: the
                # alias already points at the replacement — admit there.
                # Terminates because each retry needs another swap to have
                # moved the pointer; an unmoved pointer is a real failure
                if entry is self.entry_for(alias):
                    raise
        with self._stats_lock:
            self._streams["started"] += 1
        return stream

    def _finished(self, req: Request) -> None:
        key = ("cancelled" if req.finish_reason == "cancelled" else
               "failed" if req.finish_reason == "error" else "completed")
        with self._stats_lock:
            self._streams[key] += 1

    # --- observability / teardown ---------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            entries = dict(self._aliases)
        engines = {a: {"engine": e.label, **e.service.stats()}
                   for a, e in entries.items()}
        with self._stats_lock:
            out: Dict[str, Any] = {"streams": dict(self._streams),
                                   "engine_swaps": self._swaps}
        # the default alias's scheduler stats at top level keep the
        # /metrics "generate" section shape stable for dashboards — zeroed
        # before the first engine load so scrapers never hit missing keys
        out.update({"steps": 0, "active_slots": 0, "pending": 0,
                    "num_slots": self.num_slots, "completed": 0,
                    "cancelled": 0,
                    "request_latency_p50_ms": 0.0,
                    "request_latency_p95_ms": 0.0,
                    "ttft_p50_ms": 0.0, "ttft_p95_ms": 0.0,
                    "inter_token_p50_ms": 0.0, "inter_token_p95_ms": 0.0})
        default = engines.get(self.default_alias)
        if default is not None:
            out.update({k: v for k, v in default.items() if k != "engine"})
        out["engines"] = engines
        return out

    def close(self) -> None:
        with self._lock:
            self._closed = True
            entries = list(self._aliases.values())
            self._aliases.clear()
        for e in entries:
            e.service.close()
