"""Unified request plane: admission control, deadlines, and load shedding.

Every inference-plane request (/v1/infer, /v1/detect, /v1/generate) gets a
``RequestContext`` at the HTTP boundary — arrival time, absolute deadline,
priority class, client tag, trace id — which is threaded through the
coalescer, the continuous-batching scheduler, the generation service, and
the lifecycle manager's traffic accounting.  The layers below no longer
keep ad-hoc per-request bookkeeping; they read the context.

``AdmissionController`` is the overload policy in one place:

  * **Bounded queues** — each plane ("infer", "generate") admits at most
    its budget in cost units at a time.  Excess load is SHED at admission
    with a 429 + ``Retry-After`` instead of growing an unbounded queue
    until everyone's latency is ruined.  The infer plane costs ROWS (the
    thing that occupies device batches); the generate plane costs TOKENS
    — prompt length + requested ``max_new_tokens`` — because a decode
    request's hold on the device is proportional to its token footprint,
    not its prompt count: a single 100k-token request must not slip under
    a row-count budget as "1 unit" (``plane_budgets`` overrides the
    default ``max_queue`` per plane, in that plane's units).

  * **Cheapest-first rejection** — two priority classes.  ``bulk`` may
    only occupy ``bulk_fraction`` of a plane's budget, so under pressure
    bulk traffic sheds first while ``interactive`` still admits; an
    interactive request is refused only when the whole budget is in use.

  * **Deadlines** — a request past its deadline is dropped at the next
    hand-off (admission, coalescer group formation, scheduler admit)
    BEFORE it costs a forward pass, and returned as 504.  Misses are
    counted per stage.

  * **Retry-After** — computed per plane from the observed RELEASE rate
    (EWMA of the gap between budget releases, per cost unit) times the
    current backlog: the hint tracks how long this plane's backlog
    actually takes to drain on this host.  Release rate — not ticket
    lifetime — because a ticket's hold time includes its own queue wait
    (and a stream's ticket lives for the whole stream), which would
    wildly overstate drain time for mixed traffic.

The controller never queues anything itself — the coalescer and scheduler
keep their own queues — it meters what those queues are allowed to hold,
which keeps the policy testable without the machinery.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

PRIORITIES = ("interactive", "bulk")

_trace_counter = itertools.count(1)


class ShedError(RuntimeError):
    """Load shed at admission (HTTP 429).  Carries the Retry-After hint."""

    def __init__(self, message: str, retry_after_s: float = 0.5):
        super().__init__(message)
        self.retry_after_s = retry_after_s


class DeadlineError(RuntimeError):
    """Deadline exceeded before useful work was spent (HTTP 504)."""


@dataclass
class RequestContext:
    """Per-request facts every layer of the request plane can read.

    ``arrival_s`` / ``deadline_s`` are ``time.perf_counter`` values (the
    clock every queue-side timestamp in this codebase already uses), so
    ``expired`` is one comparison with no clock conversions on hot paths.
    """

    arrival_s: float
    deadline_s: Optional[float] = None
    priority: str = "interactive"
    client: Optional[str] = None
    trace_id: str = ""
    # live telemetry.Trace attached by the server's flight recorder (None
    # when tracing is off); planes read it duck-typed and guard on None
    trace: Optional[Any] = None

    def remaining_s(self, now: Optional[float] = None) -> Optional[float]:
        if self.deadline_s is None:
            return None
        return self.deadline_s - (now if now is not None
                                  else time.perf_counter())

    def expired(self, now: Optional[float] = None) -> bool:
        return (self.deadline_s is not None
                and (now if now is not None
                     else time.perf_counter()) >= self.deadline_s)

    def describe(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"priority": self.priority,
                               "trace_id": self.trace_id}
        if self.client:
            out["client"] = self.client
        rem = self.remaining_s()
        if rem is not None:
            out["deadline_remaining_ms"] = 1e3 * rem
        return out


def make_context(req: Dict[str, Any],
                 headers: Optional[Dict[str, str]] = None, *,
                 arrival_s: Optional[float] = None,
                 default_deadline_ms: Optional[float] = None
                 ) -> RequestContext:
    """Build a context from a parsed request body (and the already-lowered
    ``x-flexserve-*`` headers the HTTP layer captured).  Body fields win
    over headers; ``default_deadline_ms`` applies when neither names one.

    Raises ValueError on a malformed priority/deadline (the route layer
    maps it to 400).
    """
    headers = headers or {}
    arrival = arrival_s if arrival_s is not None else time.perf_counter()
    priority = req.get("priority", headers.get("x-flexserve-priority",
                                               "interactive"))
    if priority not in PRIORITIES:
        raise ValueError(f"'priority' must be one of {PRIORITIES}, "
                         f"got {priority!r}")
    raw = req.get("deadline_ms", headers.get("x-flexserve-deadline-ms"))
    if raw is None:
        deadline_ms = default_deadline_ms
    else:
        try:
            deadline_ms = float(raw)
        except (TypeError, ValueError):
            raise ValueError(f"'deadline_ms' must be a number, "
                             f"got {raw!r}") from None
        if deadline_ms <= 0:
            raise ValueError("'deadline_ms' must be > 0")
    deadline = (arrival + deadline_ms / 1e3
                if deadline_ms is not None else None)
    trace = str(req.get("trace_id", headers.get("x-request-id", ""))
                or f"req-{next(_trace_counter):06d}")
    client = req.get("client", headers.get("x-flexserve-client"))
    return RequestContext(arrival, deadline, priority,
                          str(client) if client is not None else None, trace)


@dataclass
class Ticket:
    """One admitted request's hold on a plane's budget; released when the
    request leaves the plane (finished, shed, or errored).  Idempotent
    under concurrent callers — a disconnect can race the terminal event,
    and a double decrement would silently widen the queue bound."""

    controller: "AdmissionController"
    plane: str
    priority: str
    cost: int
    admitted_s: float
    client: Optional[str] = None       # quota tag (None: quotas disabled)
    _released: bool = field(default=False)

    def release(self) -> None:
        self.controller._release(self)


class AdmissionController:
    """Bounded-queue admission with priority-aware shedding (see module
    docstring).  ``max_queue`` is in COST units (input rows / prompts),
    the thing that actually occupies device batches — a 16-row request
    takes 16x the budget of a 1-row request."""

    _EWMA_ALPHA = 0.2

    MAX_CLIENT_TAGS = 1024        # distinct tags tracked per plane

    def __init__(self, *, max_queue: int = 64, bulk_fraction: float = 0.5,
                 default_deadline_ms: Optional[float] = None,
                 min_retry_after_s: float = 0.05,
                 plane_budgets: Optional[Dict[str, int]] = None,
                 client_weights: Optional[Dict[str, float]] = None):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self.max_queue = max_queue
        self.bulk_fraction = bulk_fraction
        self.bulk_max = max(1, int(max_queue * bulk_fraction))
        # per-client quotas: ACTIVE only when a weight map is given (even
        # an empty one — unknown tags then weigh 1.0).  While several tags
        # hold budget, each is capped at its weighted share; a lone tag
        # still gets the whole plane, and any tag always gets at least
        # one request in flight (no hard starvation of big requests).
        self.client_weights = (dict(client_weights)
                               if client_weights is not None else None)
        # per-plane budget overrides, each in ITS plane's cost units
        # (e.g. {"generate": tokens}); planes not named use max_queue
        self.plane_budgets = dict(plane_budgets or {})
        for name, budget in self.plane_budgets.items():
            if budget < 1:
                raise ValueError(f"plane budget {name!r} must be >= 1")
        self.default_deadline_ms = default_deadline_ms
        self.min_retry_after_s = min_retry_after_s
        self._lock = threading.Lock()
        self._planes: Dict[str, Dict[str, Any]] = {}

    def budget_for(self, plane: str) -> int:
        return self.plane_budgets.get(plane, self.max_queue)

    def _bulk_max_for(self, plane: str) -> int:
        if plane in self.plane_budgets:
            return max(1, int(self.plane_budgets[plane]
                              * self.bulk_fraction))
        return self.bulk_max

    # --- context ----------------------------------------------------------------

    def context(self, req: Dict[str, Any],
                headers: Optional[Dict[str, str]] = None, *,
                arrival_s: Optional[float] = None) -> RequestContext:
        return make_context(req, headers, arrival_s=arrival_s,
                            default_deadline_ms=self.default_deadline_ms)

    # --- admission --------------------------------------------------------------

    def _plane(self, plane: str) -> Dict[str, Any]:
        st = self._planes.get(plane)
        if st is None:
            st = self._planes[plane] = {
                "depth": {p: 0 for p in PRIORITIES},
                "high_water": 0,
                "admitted": {p: 0 for p in PRIORITIES},
                "shed": {p: 0 for p in PRIORITIES},
                "deadline_miss": {},
                "last_release_s": None,
                "ewma_release_gap_s": None,   # per cost unit
                "clients": {},                # tag -> cost/admitted/shed
            }
        return st

    def _client(self, st: Dict[str, Any],
                tag: str) -> "tuple[str, Dict[str, Any]]":
        """(possibly folded tag, its entry) — unseen tags past the cap
        fold into ``"_overflow"`` so tag churn cannot grow memory."""
        clients = st["clients"]
        ent = clients.get(tag)
        if ent is None:
            if len(clients) >= self.MAX_CLIENT_TAGS:
                tag = "_overflow"
                ent = clients.get(tag)
                if ent is not None:
                    return tag, ent
            ent = clients[tag] = {"cost": 0, "admitted": 0, "shed": 0}
        return tag, ent

    def admit(self, plane: str, ctx: RequestContext,
              cost: int = 1) -> Ticket:
        """Admit ``cost`` units into ``plane`` or raise (504 if the request
        arrived already expired, 429 if the plane's budget is full)."""
        now = time.perf_counter()
        cost = max(1, int(cost))
        tr = ctx.trace
        with self._lock:
            st = self._plane(plane)
            if ctx.expired(now):
                miss = st["deadline_miss"]
                miss["admission"] = miss.get("admission", 0) + 1
                if tr is not None:
                    tr.event("deadline_drop", t=now, stage="admission",
                             plane=plane)
                raise DeadlineError(
                    f"deadline exceeded before admission "
                    f"({ctx.trace_id or 'request'})")
            depth = sum(st["depth"].values())
            budget = self.budget_for(plane)
            # bulk is capped at its OWN occupancy share (not total depth:
            # interactive-only load must not starve bulk out of a plane
            # with free budget), and everyone is capped at the total.
            over = depth + cost > budget
            if ctx.priority == "bulk":
                over = over or (st["depth"]["bulk"] + cost
                                > self._bulk_max_for(plane))
            # a single over-budget request still admits into an EMPTY
            # plane (otherwise it could never run at all)
            if over and depth > 0:
                st["shed"][ctx.priority] += 1
                retry = self._retry_after_locked(st, depth + cost)
                if tr is not None:
                    tr.event("shed", t=now, plane=plane, cost=cost,
                             depth=depth, budget=budget,
                             reason="queue_full",
                             retry_after_s=round(retry, 3))
                raise ShedError(
                    f"{plane} queue full "
                    f"({depth}/{budget} units, "
                    f"priority={ctx.priority})",
                    retry_after_s=retry)
            tag = None
            if self.client_weights is not None:
                tag, ent = self._client(st, ctx.client or "_untagged")
                # weighted-share quota: enforced only while OTHER tags
                # hold budget (a lone tag gets the whole plane), and a
                # tag holding nothing always admits one request
                holders = [t for t, e in st["clients"].items()
                           if e["cost"] > 0 and t != tag]
                if holders and ent["cost"] > 0:
                    w = self.client_weights.get(tag, 1.0)
                    wsum = w + sum(self.client_weights.get(t, 1.0)
                                   for t in holders)
                    share = budget * w / wsum
                    if ent["cost"] + cost > share:
                        ent["shed"] += 1
                        st["shed"][ctx.priority] += 1
                        retry = self._retry_after_locked(
                            st, ent["cost"] + cost)
                        if tr is not None:
                            tr.event("shed", t=now, plane=plane,
                                     cost=cost, reason="client_quota",
                                     client=tag, held=ent["cost"],
                                     share=round(share, 1),
                                     retry_after_s=round(retry, 3))
                        raise ShedError(
                            f"{plane} quota for client {tag!r} full "
                            f"({ent['cost']}/{share:.0f} of "
                            f"{budget} units)",
                            retry_after_s=retry)
                ent["cost"] += cost
                ent["admitted"] += 1
            st["depth"][ctx.priority] += cost
            st["admitted"][ctx.priority] += 1
            st["high_water"] = max(st["high_water"], depth + cost)
        if tr is not None:
            tr.event("admitted", t=now, plane=plane, cost=cost,
                     depth=depth + cost, budget=budget)
        return Ticket(self, plane, ctx.priority, cost, now, client=tag)

    def _release(self, ticket: Ticket) -> None:
        now = time.perf_counter()
        with self._lock:
            if ticket._released:          # idempotent under the lock:
                return                    # cancel can race the terminal
            ticket._released = True
            st = self._plane(ticket.plane)
            st["depth"][ticket.priority] = max(
                0, st["depth"][ticket.priority] - ticket.cost)
            if ticket.client is not None:
                ent = st["clients"].get(ticket.client)
                if ent is not None:
                    ent["cost"] = max(0, ent["cost"] - ticket.cost)
            # drain-rate estimate: gap between consecutive releases,
            # normalized per cost unit released — sampled only while the
            # plane is still BUSY, so the gap measures service, not the
            # idle time since the last burst (an overnight gap would
            # poison the hint for every release that follows).  Hints
            # only need to be accurate under load, and under load the
            # plane is busy at release time.
            last = st["last_release_s"]
            st["last_release_s"] = now
            if last is not None and sum(st["depth"].values()) > 0:
                gap_unit = (now - last) / max(ticket.cost, 1)
                prev = st["ewma_release_gap_s"]
                st["ewma_release_gap_s"] = (
                    gap_unit if prev is None else
                    (1 - self._EWMA_ALPHA) * prev
                    + self._EWMA_ALPHA * gap_unit)

    MAX_RETRY_AFTER_S = 60.0      # never tell a client to go away for days

    def _retry_after_locked(self, st: Dict[str, Any],
                            backlog_units: int) -> float:
        gap = st["ewma_release_gap_s"]
        unit = gap if gap is not None else 0.01
        return min(max(self.min_retry_after_s, unit * backlog_units),
                   self.MAX_RETRY_AFTER_S)

    # --- deadline hand-offs -----------------------------------------------------

    def deadline_miss(self, plane: str, stage: str) -> None:
        """Record a drop at a downstream hand-off (coalescer group
        formation, scheduler admit, decode tick)."""
        with self._lock:
            miss = self._plane(plane)["deadline_miss"]
            miss[stage] = miss.get(stage, 0) + 1

    # --- observability ----------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            planes = {
                name: {
                    "depth": dict(st["depth"]),
                    "depth_total": sum(st["depth"].values()),
                    "budget": self.budget_for(name),
                    "high_water": st["high_water"],
                    "admitted": dict(st["admitted"]),
                    "shed": dict(st["shed"]),
                    "deadline_miss": dict(st["deadline_miss"]),
                    "ewma_release_gap_ms": (
                        1e3 * st["ewma_release_gap_s"]
                        if st["ewma_release_gap_s"] is not None else None),
                    **({"clients": {t: dict(e)
                                    for t, e in st["clients"].items()}}
                       if self.client_weights is not None else {}),
                }
                for name, st in self._planes.items()}
            return {
                "max_queue": self.max_queue,
                "bulk_max": self.bulk_max,
                "default_deadline_ms": self.default_deadline_ms,
                "quotas_enabled": self.client_weights is not None,
                "planes": planes,
            }
