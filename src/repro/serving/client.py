"""Minimal HTTP client for FlexServe endpoints (raw sockets).

Connections are persistent (HTTP/1.1 keep-alive) and thread-local: each
client thread reuses one TCP connection across requests, with TCP_NODELAY
so small request/response bodies are never Nagle-stalled.  Requests go out
as ONE send; responses are parsed with a minimal header scan (status +
Content-Length / Transfer-Encoding) — the same leanness as the server
side, so concurrent benchmarking measures the endpoint, not stdlib HTTP
machinery.  A stale connection (server restart, timeout) is transparently
re-opened once.

Streaming: ``generate_stream`` issues a ``"stream": true`` generate and
returns an iterator of JSON events, parsed incrementally from the chunked
response as the server flushes each token.  The iterator must be consumed
to the terminal ("done"/"error") event to keep the connection reusable;
``close()`` abandons a stream mid-flight (the server notices the
disconnect and cancels the request).

Resilience: every non-2xx body carries the server's structured error
taxonomy (``{"error": {"code", "message", "retryable", "trace_id"}}``).
The client raises a TYPED error keyed off ``code`` (``QueueFullError``,
``UnavailableError``, ...) and retries exactly the errors the server
marked ``retryable`` — with capped exponential backoff plus jitter,
honoring the ``Retry-After`` hint when present.  Unstructured bodies
(older servers, proxies) fall back to the status-based
``retry_statuses`` list.  Delivery metadata rides on the response object
(``resp.attempts``).  Probe routes (``health``/``healthz``) never retry:
they exist to OBSERVE the 503.

Hedging (off by default): construct with ``hedge_ms=<float>`` or
``hedge_ms="p95"`` and the idempotent unary routes (``infer``,
``detect``) fire a BACKUP copy of any request still unanswered after the
hedge delay, on its own connection; the first response wins and the
loser's connection is torn down (the server sees a disconnect).  This
trades duplicate work for tail latency — classic tail-at-scale hedging.
"""

from __future__ import annotations

import collections
import datetime
import email.utils
import json
import math
import queue
import random
import socket
import threading
import time
import urllib.parse
from typing import Any, Dict, Iterator, Optional, Sequence, Tuple


def parse_retry_after(val: bytes) -> Optional[float]:
    """Lenient ``Retry-After`` parse -> non-negative seconds, or None.

    RFC 9110 allows two forms: delta-seconds and an HTTP-date.  The old
    ``float(val)`` parse discarded the date form entirely and — worse —
    accepted ``nan``/``inf``/negatives, which poisoned the backoff math
    (``time.sleep(nan)`` raises mid-retry).  Anything unusable returns
    None and the client falls back to capped exponential backoff."""
    text = val.strip().decode("latin-1", "replace")
    if not text:
        return None
    try:
        secs = float(text)
    except ValueError:
        try:
            when = email.utils.parsedate_to_datetime(text)
        except (TypeError, ValueError):
            return None
        if when is None:
            return None
        if when.tzinfo is None:
            when = when.replace(tzinfo=datetime.timezone.utc)
        secs = when.timestamp() - time.time()
    if math.isnan(secs) or math.isinf(secs):
        return None
    return max(0.0, secs)


class HTTPStatusError(RuntimeError):
    """Non-200 response after any retries.

    Carries the status code plus the server's structured error fields:
    ``code`` (machine-readable taxonomy entry), ``retryable`` (whether
    the server says a retry can help), ``trace_id`` (for ``trace()``),
    and ``structured`` (False when the body wasn't a taxonomy body —
    the retry decision then falls back to ``retry_statuses``)."""

    def __init__(self, status: int, message: str,
                 retry_after_s: Optional[float] = None, *,
                 code: Optional[str] = None,
                 retryable: bool = False,
                 trace_id: Optional[str] = None,
                 structured: bool = False):
        super().__init__(message)
        self.status = status
        self.retry_after_s = retry_after_s
        self.code = code or "internal"
        self.retryable = retryable
        self.trace_id = trace_id
        self.structured = structured


class BadRequestError(HTTPStatusError):
    """``code: bad_request`` — the request itself is malformed."""


class NotFoundError(HTTPStatusError):
    """``code: not_found`` — unknown route/model/alias/trace."""


class ConflictError(HTTPStatusError):
    """``code: conflict`` — state precondition failed (409)."""


class QueueFullError(HTTPStatusError):
    """``code: queue_full`` — admission shed the request (retryable)."""


class RequestTimeoutError(HTTPStatusError):
    """``code: timeout`` — the server timed the request out (408)."""


class ClientClosedError(HTTPStatusError):
    """``code: client_closed`` — the server recorded a client abort."""


class UnavailableError(HTTPStatusError):
    """``code: unavailable`` — endpoint not servable right now
    (startup, hot swap, zero ready replicas); retryable."""


class DeadlineExceededError(HTTPStatusError):
    """``code: deadline_exceeded`` — the request's own deadline passed
    before the work finished; retrying cannot help THIS deadline."""


class InternalServerError(HTTPStatusError):
    """``code: internal`` — unexpected server-side failure."""


# taxonomy code -> typed error class (unknown codes raise the base class)
ERROR_TYPES: Dict[str, type] = {
    "bad_request": BadRequestError,
    "not_found": NotFoundError,
    "conflict": ConflictError,
    "queue_full": QueueFullError,
    "timeout": RequestTimeoutError,
    "client_closed": ClientClosedError,
    "unavailable": UnavailableError,
    "deadline_exceeded": DeadlineExceededError,
    "internal": InternalServerError,
}

# status -> (code, retryable) fallback for unstructured bodies; mirrors
# the server-side taxonomy so old/new clients classify identically
_STATUS_FALLBACK: Dict[int, Tuple[str, bool]] = {
    400: ("bad_request", False), 404: ("not_found", False),
    405: ("not_found", False), 408: ("timeout", True),
    409: ("conflict", False), 413: ("bad_request", False),
    429: ("queue_full", True), 499: ("client_closed", False),
    500: ("internal", False), 501: ("internal", False),
    503: ("unavailable", True), 504: ("deadline_exceeded", False),
}


def make_error(status: int, raw: bytes, retry_after: Optional[float],
               trace_id: Optional[str], context: str) -> HTTPStatusError:
    """Parse a non-2xx body into the right typed error.  A structured
    ``{"error": {...}}`` taxonomy body supplies code/retryable/trace_id
    directly; anything else (legacy flat ``{"error": "msg"}``, proxies,
    empty bodies) falls back to the status map with
    ``structured=False``."""
    try:
        data = json.loads(raw or b"{}")
    except ValueError:
        data = {}
    err = data.get("error") if isinstance(data, dict) else None
    f_code, f_retry = _STATUS_FALLBACK.get(
        status, ("bad_request" if 400 <= status < 500 else "internal",
                 False))
    if isinstance(err, dict) and "code" in err:
        code = str(err["code"])
        message = str(err.get("message", ""))
        retryable = bool(err.get("retryable", f_retry))
        trace_id = err.get("trace_id") or trace_id
        structured = True
    else:
        code, retryable, structured = f_code, f_retry, False
        message = str(err if err is not None else (data or raw[:200]))
    cls = ERROR_TYPES.get(code, HTTPStatusError)
    return cls(status, f"{context} -> {status} [{code}]: {message}",
               retry_after, code=code, retryable=retryable,
               trace_id=trace_id, structured=structured)


class Response(dict):
    """A route's JSON payload plus client-side delivery metadata
    (``attempts`` — how many sends it took, 1 when nothing was shed;
    ``trace_id`` — the server's ``X-Request-Id`` echo, usable with
    ``trace()`` to fetch the request's recorded timeline)."""

    attempts: int = 1
    trace_id: Optional[str] = None


class _Connection:
    """One persistent keep-alive connection."""

    def __init__(self, host: str, port: int, timeout: float):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.rfile = self.sock.makefile("rb")

    def close(self) -> None:
        try:
            self.rfile.close()
            self.sock.close()
        except OSError:
            pass

    def _send_and_head(self, request: bytes
                       ) -> Tuple[int, int, bool, Optional[float],
                                  Optional[str]]:
        """Send + parse the response head ->
        (status, length, chunked, retry_after_s, trace_id)."""
        self.sock.sendall(request)
        status_line = self.rfile.readline(65537)
        if not status_line:
            raise ConnectionError("server closed the connection")
        parts = status_line.split(None, 2)
        if len(parts) < 2 or not parts[0].startswith(b"HTTP/"):
            raise ConnectionError(f"malformed status line {status_line!r}")
        status = int(parts[1])
        length, chunked, retry_after, trace_id = 0, False, None, None
        while True:
            h = self.rfile.readline(65537)
            if h in (b"\r\n", b"\n", b""):
                break
            key, _, val = h.partition(b":")
            key = key.strip().lower()
            if key == b"content-length":
                length = int(val)
            elif key == b"transfer-encoding":
                chunked = b"chunked" in val.lower()
            elif key == b"retry-after":
                retry_after = parse_retry_after(val)
            elif key == b"x-request-id":
                trace_id = val.strip().decode("latin-1")
        return status, length, chunked, retry_after, trace_id

    def roundtrip(self, request: bytes
                  ) -> Tuple[int, bytes, Optional[float], Optional[str]]:
        status, length, chunked, retry_after, trace_id = \
            self._send_and_head(request)
        if chunked:
            return status, b"".join(self.read_chunks()), retry_after, \
                trace_id
        return (status, self.rfile.read(length) if length else b"",
                retry_after, trace_id)

    def stream(self, request: bytes
               ) -> Tuple[int, Iterator[bytes], Optional[float]]:
        """-> (status, iterator of newline-delimited body records,
        retry_after_s).

        A chunked response is parsed chunk by chunk as the server flushes
        (this is what makes client-side streaming real: each record is
        yielded the moment its chunk arrives); a Content-Length response
        degenerates to a single record.
        """
        status, length, chunked, retry_after, _ = \
            self._send_and_head(request)
        if not chunked:
            body = self.rfile.read(length) if length else b""
            return status, iter([body] if body else []), retry_after
        return status, self._iter_records(), retry_after

    def read_chunks(self) -> Iterator[bytes]:
        """Decode chunked transfer encoding: size-line, payload, CRLF,
        terminated by a zero-size chunk."""
        while True:
            size_line = self.rfile.readline(65537)
            if not size_line:
                raise ConnectionError("truncated chunked response")
            try:
                size = int(size_line.split(b";", 1)[0], 16)
            except ValueError:
                raise ConnectionError(
                    f"malformed chunk size {size_line!r}") from None
            if size == 0:
                self.rfile.readline(65537)        # trailing CRLF
                return
            data = self.rfile.read(size)
            if len(data) < size:
                raise ConnectionError("truncated chunk payload")
            self.rfile.read(2)                    # chunk-terminating CRLF
            yield data

    def _iter_records(self) -> Iterator[bytes]:
        """Split the chunk stream into newline-delimited records,
        tolerating records that span chunk boundaries."""
        buf = b""
        for chunk in self.read_chunks():
            buf += chunk
            while b"\n" in buf:
                line, buf = buf.split(b"\n", 1)
                if line.strip():
                    yield line
        if buf.strip():
            yield buf


class FlexServeClient:
    def __init__(self, host: str = "127.0.0.1", port: int = 8000,
                 timeout: float = 60.0, *, retries: int = 3,
                 backoff_s: float = 0.05, max_backoff_s: float = 2.0,
                 retry_statuses: Sequence[int] = (429, 503),
                 hedge_ms: Any = None):
        self.host, self.port, self.timeout = host, port, timeout
        self.retries = retries
        self.backoff_s = backoff_s
        self.max_backoff_s = max_backoff_s
        self.retry_statuses = tuple(retry_statuses)
        # hedging: None = off, a number = fixed delay in ms, "p95"/"auto"
        # = adapt the delay to the observed per-route p95 latency
        if hedge_ms is not None and not isinstance(hedge_ms, (int, float)) \
                and hedge_ms not in ("p95", "auto"):
            raise ValueError(
                "hedge_ms must be None, a number (ms), 'p95' or 'auto'")
        self.hedge_ms = hedge_ms
        self.hedges = 0                    # backups actually launched
        self.hedge_wins = 0                # ... that beat the primary
        self._latency: Dict[str, "collections.deque"] = {}
        self._local = threading.local()

    def _conn(self) -> _Connection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = _Connection(self.host, self.port, self.timeout)
            self._local.conn = conn
        return conn

    def close(self) -> None:
        """Close this thread's persistent connection (if any)."""
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
            self._local.conn = None

    def _raw_request(self, method: str, path: str,
                     payload: Optional[Dict[str, Any]] = None) -> bytes:
        body = json.dumps(payload).encode() if payload is not None else b""
        return (f"{method} {path} HTTP/1.1\r\n"
                f"Host: {self.host}:{self.port}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"\r\n").encode("latin-1") + body

    def _roundtrip_once(self, request: bytes
                        ) -> Tuple[int, bytes, Optional[float],
                                   Optional[str]]:
        """One send with the stale-keep-alive reconnect, no status retry."""
        for attempt in (0, 1):
            fresh = getattr(self._local, "conn", None) is None
            try:
                return self._conn().roundtrip(request)
            except socket.timeout:
                # The server may still be processing; resending would
                # execute a non-idempotent POST twice.  Never retry.
                self.close()
                raise
            except (ConnectionError, OSError):
                self.close()
                # A REUSED keep-alive connection dying on first read is the
                # stale-connection case — safe to reconnect once.  A fresh
                # connection failing is a real error.
                if attempt or fresh:
                    raise
        raise ConnectionError("unreachable")

    def _backoff_delay(self, attempt: int,
                       retry_after: Optional[float]) -> float:
        """Server hint when given, else capped exponential; jittered so a
        shed herd does not return in lockstep.  Never sleeps less than
        the hint, never more than ``max_backoff_s`` (the jitter is capped
        too — 'capped' must mean the number in the constructor)."""
        if (retry_after is None or math.isnan(retry_after)
                or retry_after < 0):
            # unusable hint (absent, or hostile header that slipped past
            # parsing): fall back to capped exponential — never let a
            # header value reach time.sleep() unvalidated
            retry_after = None
        base = (retry_after if retry_after is not None
                else self.backoff_s * (2 ** (attempt - 1)))
        base = min(base, self.max_backoff_s)
        return min(base + random.uniform(0, base / 2), self.max_backoff_s)

    def _should_retry(self, err: HTTPStatusError) -> bool:
        """Structured bodies are authoritative — retry iff the server
        says the error is retryable.  Unstructured bodies (legacy
        servers, intermediaries) fall back to the status list."""
        if err.structured:
            return err.retryable
        return err.status in self.retry_statuses

    def _record_latency(self, path: str, dt_s: float) -> None:
        lat = self._latency.get(path)
        if lat is None:
            lat = self._latency.setdefault(
                path, collections.deque(maxlen=256))
        lat.append(dt_s)

    def _hedge_delay_s(self, path: str) -> Optional[float]:
        """The current hedge delay for a route, or None when hedging is
        off.  In "p95" mode the delay tracks the observed per-route p95
        (50 ms until enough samples exist)."""
        if self.hedge_ms is None:
            return None
        if isinstance(self.hedge_ms, (int, float)):
            return max(0.0, float(self.hedge_ms) / 1e3)
        lat = self._latency.get(path)
        if lat is not None and len(lat) >= 8:
            xs = sorted(lat)
            return xs[min(len(xs) - 1, int(0.95 * len(xs)))]
        return 0.05

    def _hedged_roundtrip(self, request: bytes, delay_s: float
                          ) -> Tuple[int, bytes, Optional[float],
                                     Optional[str]]:
        """One logical send with tail-latency hedging: a backup copy
        goes out on its OWN connection if the primary hasn't answered
        within ``delay_s``; the first HTTP response wins and the loser's
        connection is closed (the server observes a disconnect and, on
        streaming-free unary routes, simply wastes one forward).  Both
        attempts use dedicated connections so the thread-local keep-alive
        connection never ends up with an orphaned in-flight response."""
        results: "queue.Queue[Tuple[str, Any, Any]]" = queue.Queue()
        conns: Dict[str, _Connection] = {}
        state = {"done": False}

        def attempt(role: str) -> None:
            conn = None
            try:
                conn = _Connection(self.host, self.port, self.timeout)
                conns[role] = conn
                results.put((role, conn.roundtrip(request), None))
            except BaseException as e:      # noqa: BLE001 — reported below
                results.put((role, None, e))
            finally:
                # covers the race where the loser's connection is created
                # after the winner's teardown sweep ran
                if conn is not None and state["done"]:
                    conn.close()

        threading.Thread(target=attempt, args=("primary",),
                         daemon=True).start()
        pending, backup_started = 1, False
        winner = None
        first_exc: Optional[BaseException] = None
        try:
            while pending:
                if not backup_started:
                    try:
                        role, out, exc = results.get(timeout=delay_s)
                    except queue.Empty:
                        backup_started = True
                        self.hedges += 1
                        threading.Thread(target=attempt, args=("backup",),
                                         daemon=True).start()
                        pending += 1
                        continue
                else:
                    role, out, exc = results.get()
                pending -= 1
                if exc is None:
                    winner = (role, out)
                    break
                first_exc = first_exc or exc
            if winner is None:
                raise first_exc or ConnectionError("hedge: no attempts ran")
            if winner[0] == "backup":
                self.hedge_wins += 1
            return winner[1]
        finally:
            state["done"] = True
            for conn in list(conns.values()):
                conn.close()

    def _request(self, method: str, path: str,
                 payload: Optional[Dict[str, Any]] = None, *,
                 retries: Optional[int] = None,
                 ok: Tuple[int, ...] = (200,),
                 hedge: bool = False) -> Response:
        request = self._raw_request(method, path, payload)
        retries = self.retries if retries is None else retries
        attempts = 0
        while True:
            delay = self._hedge_delay_s(path) if hedge else None
            t0 = time.perf_counter()
            if delay is not None:
                status, raw, retry_after, trace_id = \
                    self._hedged_roundtrip(request, delay)
            else:
                status, raw, retry_after, trace_id = \
                    self._roundtrip_once(request)
            attempts += 1
            if status in ok:
                self._record_latency(path, time.perf_counter() - t0)
                resp = Response(json.loads(raw or b"{}"))
                resp.attempts = attempts
                resp.trace_id = trace_id
                return resp
            err = make_error(status, raw, retry_after, trace_id,
                             f"{method} {path}")
            if self._should_retry(err) and attempts <= retries:
                # retryable errors are REJECTIONS (no server-side work
                # happened): resending cannot double-execute the POST
                time.sleep(self._backoff_delay(attempts, retry_after))
                continue
            raise err

    def health(self) -> Dict[str, Any]:
        return self._request("GET", "/health", retries=0)

    def healthz(self) -> Dict[str, Any]:
        """Readiness probe — raises HTTPStatusError("... 503 ...") until
        the endpoint has >=1 loaded model and a live coalescer.  Never
        retried: this route exists to observe the 503."""
        return self._request("GET", "/healthz", retries=0)

    def metrics(self, format: str = "json"):
        """Endpoint metrics: ``format="json"`` returns the structured
        dict, ``format="prometheus"`` the text exposition (a str)."""
        if format == "json":
            return self._request("GET", "/metrics")
        status, raw, retry_after, trace_id = self._roundtrip_once(
            self._raw_request("GET", f"/metrics?format={format}"))
        if status != 200:
            raise make_error(status, raw, retry_after, trace_id,
                             f"GET /metrics?format={format}")
        return raw.decode("utf-8")

    def trace(self, trace_id: str) -> Dict[str, Any]:
        """Fetch the flight recorder's timeline for one request (by the
        ``trace_id`` echoed on responses as ``X-Request-Id`` / carried in
        stream events).  404 -> HTTPStatusError (evicted or unknown)."""
        return self._request(
            "GET", f"/v1/trace/{urllib.parse.quote(trace_id, safe='')}",
            retries=0)

    def traces(self, **filters: Any) -> Dict[str, Any]:
        """Flight recorder index: in-flight + recently completed traces.
        Keyword filters pass through as query parameters — ``status=504``,
        ``client="tenant-a"``, ``min_duration_ms=250``, ``limit=50``."""
        qs = urllib.parse.urlencode(
            {k: v for k, v in filters.items() if v is not None})
        return self._request("GET", f"/v1/traces{'?' + qs if qs else ''}",
                             retries=0)

    def usage(self, client: Optional[str] = None,
              version: Optional[str] = None) -> Dict[str, Any]:
        """Per-client / per-version cost attribution (GET /v1/usage),
        optionally narrowed to one client tag and/or version label."""
        qs = urllib.parse.urlencode(
            {k: v for k, v in (("client", client), ("version", version))
             if v is not None})
        return self._request("GET", f"/v1/usage{'?' + qs if qs else ''}",
                             retries=0)

    def slo(self, window_s: Optional[float] = None) -> Dict[str, Any]:
        """SLO autopilot status: policies with their latest evaluation,
        the decision audit log, and an SLI snapshot (GET /v1/slo)."""
        qs = f"?window_s={window_s}" if window_s is not None else ""
        return self._request("GET", f"/v1/slo{qs}", retries=0)

    def start_profile(self, duration_ms: int = 1000,
                      mode: str = "auto") -> Dict[str, Any]:
        """Kick off a time-boxed device-profile capture (202 Accepted);
        409 while one is already running, 503 when profiling is off."""
        return self._request("POST", "/v1/debug/profile",
                             {"duration_ms": duration_ms, "mode": mode},
                             retries=0, ok=(200, 202))

    def profile_status(self) -> Dict[str, Any]:
        return self._request("GET", "/v1/debug/profile", retries=0)

    def models(self) -> Dict[str, Any]:
        return self._request("GET", "/v1/models")

    def _model_path(self, name: str, action: str = "") -> str:
        # member names may contain '#' (fragment delimiter): encode them
        return (f"/v1/models/{urllib.parse.quote(name, safe='')}"
                f"{'/' + action if action else ''}")

    def model_status(self, name: str) -> Dict[str, Any]:
        return self._request("GET", self._model_path(name))

    def load_model(self, name: str, version: Optional[int] = None,
                   alias: Optional[str] = None,
                   warm: bool = True) -> Dict[str, Any]:
        body: Dict[str, Any] = {"warm": warm}
        if version is not None:
            body["version"] = version
        if alias is not None:
            body["alias"] = alias
        return self._request("POST", self._model_path(name, "load"), body)

    def unload_model(self, name: str,
                     version: Optional[int] = None) -> Dict[str, Any]:
        body = {} if version is None else {"version": version}
        return self._request("POST", self._model_path(name, "unload"), body)

    def rollback_model(self, name: str,
                       alias: Optional[str] = None) -> Dict[str, Any]:
        body = {} if alias is None else {"alias": alias}
        return self._request("POST", self._model_path(name, "rollback"), body)

    def gc_model(self, name: str, keep_last_n: int) -> Dict[str, Any]:
        """Retention GC: delete store versions beyond the newest
        ``keep_last_n`` (versions referenced by a serving alias survive)."""
        return self._request("POST", self._model_path(name, "gc"),
                             {"keep_last_n": keep_last_n})

    # --- generation-engine lifecycle ------------------------------------------

    def _engine_path(self, name: str, action: str) -> str:
        return (f"/v1/engines/{urllib.parse.quote(name, safe='')}/{action}")

    def engines(self) -> Dict[str, Any]:
        return self._request("GET", "/v1/engines")

    def load_engine(self, name: str, version: Optional[int] = None,
                    alias: Optional[str] = None) -> Dict[str, Any]:
        body: Dict[str, Any] = {}
        if version is not None:
            body["version"] = version
        if alias is not None:
            body["alias"] = alias
        return self._request("POST", self._engine_path(name, "load"), body)

    def rollback_engine(self, name: str,
                        alias: Optional[str] = None) -> Dict[str, Any]:
        body = {} if alias is None else {"alias": alias}
        return self._request("POST", self._engine_path(name, "rollback"),
                             body)

    # --- replica admin --------------------------------------------------------

    def replicas(self) -> Dict[str, Any]:
        """Per-replica lifecycle states + pool counters
        (GET /v1/replicas); works in single-service mode too."""
        return self._request("GET", "/v1/replicas", retries=0)

    def cordon_replica(self, rid: int,
                       reason: Optional[str] = None) -> Dict[str, Any]:
        """Drain-aware operator cordon: the replica takes no new work but
        finishes what it has.  409 without a replica pool."""
        body = {} if reason is None else {"reason": reason}
        return self._request("POST", f"/v1/replicas/{rid}/cordon", body,
                             retries=0)

    def uncordon_replica(self, rid: int) -> Dict[str, Any]:
        return self._request("POST", f"/v1/replicas/{rid}/uncordon", {},
                             retries=0)

    def hedge_stats(self) -> Dict[str, Any]:
        """Client-side hedging counters (all zero when hedging is off)."""
        return {"enabled": self.hedge_ms is not None,
                "hedges": self.hedges, "hedge_wins": self.hedge_wins}

    @staticmethod
    def _plane_fields(body: Dict[str, Any], priority, deadline_ms,
                      client_tag, trace_id) -> Dict[str, Any]:
        for key, val in (("priority", priority),
                         ("deadline_ms", deadline_ms),
                         ("client", client_tag), ("trace_id", trace_id)):
            if val is not None:
                body[key] = val
        return body

    def infer(self, inputs: Dict[str, Any], policy: str = "soft_vote",
              target: Optional[str] = None, *,
              priority: Optional[str] = None,
              deadline_ms: Optional[float] = None,
              client_tag: Optional[str] = None,
              trace_id: Optional[str] = None) -> Dict[str, Any]:
        body: Dict[str, Any] = {"inputs": inputs, "policy": policy}
        if target is not None:
            body["target"] = target
        self._plane_fields(body, priority, deadline_ms, client_tag,
                           trace_id)
        return self._request("POST", "/v1/infer", body, hedge=True)

    def detect(self, inputs: Dict[str, Any], positive_class: int,
               policy: str = "or", threshold: float = 0.5,
               target: Optional[str] = None, *,
               priority: Optional[str] = None,
               deadline_ms: Optional[float] = None,
               client_tag: Optional[str] = None,
               trace_id: Optional[str] = None) -> Dict[str, Any]:
        body: Dict[str, Any] = {"inputs": inputs,
                                "positive_class": positive_class,
                                "policy": policy, "threshold": threshold}
        if target is not None:
            body["target"] = target
        self._plane_fields(body, priority, deadline_ms, client_tag,
                           trace_id)
        return self._request("POST", "/v1/detect", body, hedge=True)

    @staticmethod
    def _generate_body(prompts, max_new_tokens, eos_id, *,
                       temperature=None, top_k=None, top_p=None, seed=None,
                       stop=None, speculation=None, target=None,
                       priority=None, deadline_ms=None, client_tag=None,
                       trace_id=None) -> Dict[str, Any]:
        body: Dict[str, Any] = {"prompts": [list(p) for p in prompts],
                                "max_new_tokens": max_new_tokens,
                                "eos_id": eos_id}
        for key, val in (("temperature", temperature), ("top_k", top_k),
                         ("top_p", top_p), ("seed", seed), ("stop", stop),
                         ("speculation", speculation),
                         ("target", target), ("priority", priority),
                         ("deadline_ms", deadline_ms),
                         ("client", client_tag), ("trace_id", trace_id)):
            if val is not None:
                body[key] = val
        return body

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 16,
                 eos_id: Optional[int] = None,
                 **sampling: Any) -> Dict[str, Any]:
        """Blocking generate; ``sampling`` may carry temperature / top_k /
        top_p / seed / stop / speculation (False opts this request out of
        speculative decoding) / target (an engine version alias)."""
        return self._request(
            "POST", "/v1/generate",
            self._generate_body(prompts, max_new_tokens, eos_id, **sampling))

    def generate_stream(self, prompt: Sequence[int],
                        max_new_tokens: int = 16,
                        eos_id: Optional[int] = None,
                        **sampling: Any) -> Iterator[Dict[str, Any]]:
        """Streamed generate for ONE prompt: yields event dicts (see
        repro.serving.api) as the server decodes.  Consume to the terminal
        event — on a speculative engine its ``"speculation"`` summary
        carries proposed/accepted/acceptance_rate — or ``close()`` the
        client to abandon mid-stream (the server cancels the request and
        frees its slot)."""
        body = self._generate_body([prompt], max_new_tokens, eos_id,
                                   **sampling)
        body["stream"] = True
        request = self._raw_request("POST", "/v1/generate", body)
        # eager send: the request is in flight (and errors surface) before
        # the caller pulls the first event; a stale reused keep-alive
        # connection is re-opened once, exactly like _request.  A 429/503
        # rejection (head known before any event) is retried with the
        # same backoff policy as unary requests.
        attempts = 0
        while True:
            for attempt in (0, 1):
                fresh = getattr(self._local, "conn", None) is None
                try:
                    status, records, retry_after = \
                        self._conn().stream(request)
                    break
                except socket.timeout:
                    self.close()
                    raise
                except (ConnectionError, OSError):
                    self.close()
                    if attempt or fresh:
                        raise
            attempts += 1
            if status != 200:
                # drain the error body (keeps the connection reusable)
                # and classify it through the taxonomy
                err = make_error(status, b"".join(records), retry_after,
                                 None, "POST /v1/generate")
                if self._should_retry(err) and attempts <= self.retries:
                    time.sleep(self._backoff_delay(attempts, retry_after))
                    continue
                raise err
            return (json.loads(record) for record in records)
