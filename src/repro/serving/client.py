"""Minimal HTTP client for FlexServe endpoints (raw sockets).

Connections are persistent (HTTP/1.1 keep-alive) and thread-local: each
client thread reuses one TCP connection across requests, with TCP_NODELAY
so small request/response bodies are never Nagle-stalled.  Requests go out
as ONE send; responses are parsed with a minimal header scan (status +
Content-Length) — the same leanness as the server side, so concurrent
benchmarking measures the endpoint, not stdlib HTTP machinery.  A stale
connection (server restart, timeout) is transparently re-opened once.
"""

from __future__ import annotations

import json
import socket
import threading
import urllib.parse
from typing import Any, Dict, List, Optional, Sequence, Tuple


class _Connection:
    """One persistent keep-alive connection."""

    def __init__(self, host: str, port: int, timeout: float):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.rfile = self.sock.makefile("rb")

    def close(self) -> None:
        try:
            self.rfile.close()
            self.sock.close()
        except OSError:
            pass

    def roundtrip(self, request: bytes) -> Tuple[int, bytes]:
        self.sock.sendall(request)
        status_line = self.rfile.readline(65537)
        if not status_line:
            raise ConnectionError("server closed the connection")
        parts = status_line.split(None, 2)
        if len(parts) < 2 or not parts[0].startswith(b"HTTP/"):
            raise ConnectionError(f"malformed status line {status_line!r}")
        status = int(parts[1])
        length = 0
        while True:
            h = self.rfile.readline(65537)
            if h in (b"\r\n", b"\n", b""):
                break
            key, _, val = h.partition(b":")
            if key.strip().lower() == b"content-length":
                length = int(val)
        return status, self.rfile.read(length) if length else b""


class FlexServeClient:
    def __init__(self, host: str = "127.0.0.1", port: int = 8000,
                 timeout: float = 60.0):
        self.host, self.port, self.timeout = host, port, timeout
        self._local = threading.local()

    def _conn(self) -> _Connection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = _Connection(self.host, self.port, self.timeout)
            self._local.conn = conn
        return conn

    def close(self) -> None:
        """Close this thread's persistent connection (if any)."""
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
            self._local.conn = None

    def _request(self, method: str, path: str,
                 payload: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        body = json.dumps(payload).encode() if payload is not None else b""
        request = (f"{method} {path} HTTP/1.1\r\n"
                   f"Host: {self.host}:{self.port}\r\n"
                   f"Content-Type: application/json\r\n"
                   f"Content-Length: {len(body)}\r\n"
                   f"\r\n").encode("latin-1") + body
        for attempt in (0, 1):
            fresh = getattr(self._local, "conn", None) is None
            try:
                status, raw = self._conn().roundtrip(request)
                break
            except socket.timeout:
                # The server may still be processing; resending would
                # execute a non-idempotent POST twice.  Never retry.
                self.close()
                raise
            except (ConnectionError, OSError):
                self.close()
                # A REUSED keep-alive connection dying on first read is the
                # stale-connection case — safe to reconnect once.  A fresh
                # connection failing is a real error.
                if attempt or fresh:
                    raise
        data = json.loads(raw or b"{}")
        if status != 200:
            raise RuntimeError(
                f"{method} {path} -> {status}: "
                f"{data.get('error', data)}")
        return data

    def health(self) -> Dict[str, Any]:
        return self._request("GET", "/health")

    def healthz(self) -> Dict[str, Any]:
        """Readiness probe — raises RuntimeError("... 503 ...") until the
        endpoint has >=1 loaded model and a live coalescer."""
        return self._request("GET", "/healthz")

    def metrics(self) -> Dict[str, Any]:
        return self._request("GET", "/metrics")

    def models(self) -> Dict[str, Any]:
        return self._request("GET", "/v1/models")

    def _model_path(self, name: str, action: str = "") -> str:
        # member names may contain '#' (fragment delimiter): encode them
        return (f"/v1/models/{urllib.parse.quote(name, safe='')}"
                f"{'/' + action if action else ''}")

    def model_status(self, name: str) -> Dict[str, Any]:
        return self._request("GET", self._model_path(name))

    def load_model(self, name: str, version: Optional[int] = None,
                   alias: Optional[str] = None,
                   warm: bool = True) -> Dict[str, Any]:
        body: Dict[str, Any] = {"warm": warm}
        if version is not None:
            body["version"] = version
        if alias is not None:
            body["alias"] = alias
        return self._request("POST", self._model_path(name, "load"), body)

    def unload_model(self, name: str,
                     version: Optional[int] = None) -> Dict[str, Any]:
        body = {} if version is None else {"version": version}
        return self._request("POST", self._model_path(name, "unload"), body)

    def rollback_model(self, name: str,
                       alias: Optional[str] = None) -> Dict[str, Any]:
        body = {} if alias is None else {"alias": alias}
        return self._request("POST", self._model_path(name, "rollback"), body)

    def infer(self, inputs: Dict[str, Any], policy: str = "soft_vote",
              target: Optional[str] = None) -> Dict[str, Any]:
        body: Dict[str, Any] = {"inputs": inputs, "policy": policy}
        if target is not None:
            body["target"] = target
        return self._request("POST", "/v1/infer", body)

    def detect(self, inputs: Dict[str, Any], positive_class: int,
               policy: str = "or", threshold: float = 0.5,
               target: Optional[str] = None) -> Dict[str, Any]:
        body: Dict[str, Any] = {"inputs": inputs,
                                "positive_class": positive_class,
                                "policy": policy, "threshold": threshold}
        if target is not None:
            body["target"] = target
        return self._request("POST", "/v1/detect", body)

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 16,
                 eos_id: Optional[int] = None) -> Dict[str, Any]:
        return self._request("POST", "/v1/generate",
                             {"prompts": [list(p) for p in prompts],
                              "max_new_tokens": max_new_tokens,
                              "eos_id": eos_id})
