"""Minimal HTTP client for FlexServe endpoints (stdlib http.client)."""

from __future__ import annotations

import http.client
import json
from typing import Any, Dict, List, Optional, Sequence


class FlexServeClient:
    def __init__(self, host: str = "127.0.0.1", port: int = 8000,
                 timeout: float = 60.0):
        self.host, self.port, self.timeout = host, port, timeout

    def _request(self, method: str, path: str,
                 payload: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            body = json.dumps(payload).encode() if payload is not None else None
            headers = {"Content-Type": "application/json"} if body else {}
            conn.request(method, path, body=body, headers=headers)
            resp = conn.getresponse()
            data = json.loads(resp.read() or b"{}")
            if resp.status != 200:
                raise RuntimeError(
                    f"{method} {path} -> {resp.status}: "
                    f"{data.get('error', data)}")
            return data
        finally:
            conn.close()

    def health(self) -> Dict[str, Any]:
        return self._request("GET", "/health")

    def models(self) -> Dict[str, Any]:
        return self._request("GET", "/v1/models")

    def infer(self, inputs: Dict[str, Any],
              policy: str = "soft_vote") -> Dict[str, Any]:
        return self._request("POST", "/v1/infer",
                             {"inputs": inputs, "policy": policy})

    def detect(self, inputs: Dict[str, Any], positive_class: int,
               policy: str = "or", threshold: float = 0.5) -> Dict[str, Any]:
        return self._request("POST", "/v1/detect",
                             {"inputs": inputs,
                              "positive_class": positive_class,
                              "policy": policy, "threshold": threshold})

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 16,
                 eos_id: Optional[int] = None) -> Dict[str, Any]:
        return self._request("POST", "/v1/generate",
                             {"prompts": [list(p) for p in prompts],
                              "max_new_tokens": max_new_tokens,
                              "eos_id": eos_id})
