from repro.serving.client import FlexServeClient
from repro.serving.coalesce import BatchCoalescer, CoalesceError
from repro.serving.lifecycle import (LifecycleError, ModelManager,
                                     default_factory)
from repro.serving.modelstore import ModelStore, StoreError
from repro.serving.server import FlexServeApp, FlexServeServer

__all__ = ["FlexServeApp", "FlexServeServer", "FlexServeClient",
           "BatchCoalescer", "CoalesceError", "ModelStore", "StoreError",
           "ModelManager", "LifecycleError", "default_factory"]
