from repro.core.faults import FaultInjector, FaultSpec, InjectedFault
from repro.core.slo import (SLIStore, SLOController, SLOPolicy, UsageLedger,
                            load_policies)
from repro.serving.admission import (AdmissionController, DeadlineError,
                                     RequestContext, ShedError, make_context)
from repro.serving.client import (BadRequestError, ConflictError,
                                  DeadlineExceededError, FlexServeClient,
                                  HTTPStatusError, InternalServerError,
                                  NotFoundError, QueueFullError,
                                  UnavailableError)
from repro.serving.coalesce import BatchCoalescer, CoalesceError
from repro.serving.generate import (GenerationError, GenerationService,
                                    GenerationStream)
from repro.serving.lifecycle import (LifecycleError, ModelManager,
                                     default_engine_factory, default_factory)
from repro.serving.modelstore import ModelStore, StoreError
from repro.serving.replica import Replica, ReplicaPool
from repro.serving.server import FlexServeApp, FlexServeServer
from repro.serving.telemetry import (DeviceProfiler, FlightRecorder,
                                     Histogram, Reservoir, Trace,
                                     prometheus_exposition)

__all__ = ["FlexServeApp", "FlexServeServer", "FlexServeClient",
           "HTTPStatusError", "BadRequestError", "NotFoundError",
           "ConflictError", "QueueFullError", "UnavailableError",
           "DeadlineExceededError", "InternalServerError",
           "BatchCoalescer", "CoalesceError",
           "AdmissionController", "RequestContext", "ShedError",
           "DeadlineError", "make_context",
           "ModelStore", "StoreError",
           "ModelManager", "LifecycleError", "default_factory",
           "default_engine_factory", "GenerationError", "GenerationService",
           "GenerationStream",
           "ReplicaPool", "Replica",
           "FaultInjector", "FaultSpec", "InjectedFault",
           "FlightRecorder", "Trace", "Histogram", "Reservoir",
           "DeviceProfiler", "prometheus_exposition",
           "SLIStore", "SLOController", "SLOPolicy", "UsageLedger",
           "load_policies"]
