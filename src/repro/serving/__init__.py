from repro.serving.client import FlexServeClient
from repro.serving.coalesce import BatchCoalescer, CoalesceError
from repro.serving.server import FlexServeApp, FlexServeServer

__all__ = ["FlexServeApp", "FlexServeServer", "FlexServeClient",
           "BatchCoalescer", "CoalesceError"]
