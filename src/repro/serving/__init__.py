from repro.serving.client import FlexServeClient
from repro.serving.coalesce import BatchCoalescer, CoalesceError
from repro.serving.generate import (GenerationError, GenerationService,
                                    GenerationStream)
from repro.serving.lifecycle import (LifecycleError, ModelManager,
                                     default_engine_factory, default_factory)
from repro.serving.modelstore import ModelStore, StoreError
from repro.serving.server import FlexServeApp, FlexServeServer

__all__ = ["FlexServeApp", "FlexServeServer", "FlexServeClient",
           "BatchCoalescer", "CoalesceError", "ModelStore", "StoreError",
           "ModelManager", "LifecycleError", "default_factory",
           "default_engine_factory", "GenerationError", "GenerationService",
           "GenerationStream"]
