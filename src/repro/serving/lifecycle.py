"""Model lifecycle manager: hot load/unload/swap of versioned models.

The TF-Serving-shaped piece FlexServe was missing: the registry used to be
a process-lifetime dict, so changing the ensemble meant restarting the
endpoint.  ``ModelManager`` sits between the ``ModelStore`` (durable,
versioned, provenance-manifested checkpoints) and the live serving stack
(``ModelRegistry`` + per-alias ``Ensemble``s) and performs membership
changes WITHOUT dropping traffic:

  load:   restore + hash-verify the version off the hot path, register it,
          build the new ensemble state, pre-compile its batch buckets
          against a captured example batch (warm), then atomically publish
          the state and drain in-flight coalesced batches on the old one.
  unload: retire a version (refused while any alias still serves it) or a
          whole member.
  rollback: swap an alias back to the previously active version.

Version ALIASES ("stable", "canary", ...) each own a membership map and an
ensemble; ``/v1/infer``/``/v1/detect`` target one per request, so a canary
version takes real traffic next to stable — sharing the param arrays of
every member the two aliases have in common.

GENERATION ENGINES ride the same lifecycle: with a ``GenerationService``
attached, ``load_engine`` materializes a store version (restore + hash
verify, like any member), wraps it in an ``InferenceEngine``, and
hot-swaps it under an engine alias — new decode requests land on the new
engine while in-flight streams drain on the old one — with
``rollback_engine`` returning an alias to its previous version.  ``gc``
applies a keep-last-N retention policy to the store, never deleting a
version any serving alias (ensemble or engine, active or rollback
target) still references.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

import jax
import numpy as np

from repro.core.engine import InferenceEngine, SpeculativeEngine
from repro.core.ensemble import Ensemble, EnsembleMember
from repro.core.faults import FaultInjector, InjectedFault
from repro.core.registry import ModelRegistry
from repro.serving.modelstore import ModelStore


class LifecycleError(RuntimeError):
    """Admin-plane failure (unknown version, conflict, empty ensemble)."""


def default_factory(manifest: Dict[str, Any]):
    """manifest -> (Model, apply_fn, num_classes) via repro.configs.

    The manifest's ``config`` names the arch; ``reduced`` (default True)
    selects the smoke-size variant; ``num_classes`` sizes the
    classification readout (last-position logits), matching launch/serve.
    ``num_layers`` (optional) truncates the stack — how a published
    speculative DRAFT checkpoint records its reduced depth.
    """
    import dataclasses

    from repro.configs import get_config, reduce_for_smoke
    from repro.models.build import build_model

    cfg = get_config(manifest["config"])
    if manifest.get("reduced", True):
        cfg = reduce_for_smoke(cfg)
    if manifest.get("num_layers"):
        cfg = dataclasses.replace(cfg, num_layers=int(manifest["num_layers"]))
    model = build_model(cfg)
    num_classes = int(manifest.get("num_classes", 16))

    def apply(p, batch, _m=model, _c=num_classes):
        return _m.forward(p, batch)[:, -1, :_c]

    return model, apply, num_classes


def default_engine_factory(manifest: Dict[str, Any], model,
                           params) -> InferenceEngine:
    """(manifest, Model, params) -> InferenceEngine for the decode plane.

    ``max_len`` / ``max_batch`` come from the manifest when the publisher
    recorded them, so an engine version carries its own serving shape."""
    return InferenceEngine(model, params,
                           max_len=int(manifest.get("max_len", 256)),
                           max_batch=int(manifest.get("max_batch", 8)))


class ModelManager:
    """Coordinates store <-> registry <-> per-alias ensembles.

    Admin operations (load/unload/rollback) serialize on one lock and do
    all expensive work (restore, hash verify, jit warm) before the atomic
    ensemble swap, so the hot path never waits on the admin plane.
    """

    def __init__(self, store: ModelStore,
                 registry: Optional[ModelRegistry] = None, *,
                 factory: Callable[[Dict[str, Any]], Tuple[Any, Any, int]]
                 = default_factory,
                 engine_factory: Callable[[Dict[str, Any], Any, Any],
                                          InferenceEngine]
                 = default_engine_factory,
                 max_batch: int = 8,
                 class_names: Optional[List[str]] = None,
                 default_alias: str = "stable",
                 drain_timeout_s: float = 30.0,
                 faults: Optional[FaultInjector] = None):
        self.faults = faults
        self.store = store
        self.registry = registry or ModelRegistry()
        self.max_batch = max_batch
        self.class_names = class_names
        self.default_alias = default_alias
        self.drain_timeout_s = drain_timeout_s
        self._factory = factory
        self._engine_factory = engine_factory
        self.generation = None          # attach_generation() wires this
        self._engine_active: Dict[str, Tuple[str, int]] = {}
        self._engine_previous: Dict[str, Tuple[str, int]] = {}
        # speculative pairs: alias -> (draft name, draft version).  The
        # pair serves as ONE entry, so promote/demote/rollback move the
        # draft with its target and gc protects both checkpoints.
        self._engine_drafts: Dict[str, Tuple[str, int]] = {}
        self._engine_prev_drafts: Dict[str, Optional[Tuple[str, int]]] = {}
        self._admin_lock = threading.RLock()
        # alias -> {member name -> active version}; maps are replaced
        # wholesale under the admin lock, so hot-path readers always see a
        # consistent snapshot without locking.
        self._active: Dict[str, Dict[str, int]] = {}
        self._ensembles: Dict[str, Ensemble] = {}
        self._previous: Dict[Tuple[str, str], int] = {}
        self._warm_example: Optional[Dict[str, np.ndarray]] = None
        self._stats_lock = threading.Lock()
        self._counters = {"loads": 0, "unloads": 0, "swaps": 0,
                          "rollbacks": 0, "engine_loads": 0,
                          "engine_rollbacks": 0, "engine_promotes": 0,
                          "engine_demotes": 0, "gc_runs": 0}
        self._warm_total_s = 0.0
        self._last_warm_s = 0.0
        self._version_traffic: Dict[str, Dict[str, int]] = {}

    # --- hot path -------------------------------------------------------------

    @property
    def ready(self) -> bool:
        return self.default_alias in self._ensembles

    def aliases(self) -> List[str]:
        return sorted(self._ensembles)

    def ensemble_for(self, alias: Optional[Hashable] = None) -> Ensemble:
        alias = alias or self.default_alias
        try:
            return self._ensembles[alias]
        except KeyError:
            raise LifecycleError(
                f"no alias {alias!r}; available: {self.aliases()}") from None

    def forward(self, batch: Dict[str, np.ndarray],
                alias: Optional[Hashable] = None,
                ctxs: Optional[List[Any]] = None):
        """Route one (possibly coalesced) batch to an alias's ensemble.

        ``ctxs`` — the RequestContexts the coalescer merged into this
        batch — feeds per-version traffic accounting with a priority
        split, so a canary's interactive-vs-bulk exposure is visible (the
        signal canary auto-promotion will gate on)."""
        alias = alias or self.default_alias
        ens = self.ensemble_for(alias)
        if self._warm_example is None:
            # remember a one-row example of real traffic: future loads
            # pre-compile their buckets against this shape
            self._warm_example = {k: np.asarray(v)[:1].copy()
                                  for k, v in batch.items()}
        active = self._active.get(alias, {})
        rows = next(iter(batch.values())).shape[0]
        interactive = sum(1 for c in (ctxs or [])
                          if getattr(c, "priority", None) != "bulk")
        bulk = len(ctxs or []) - interactive
        if ctxs and active:
            # composite ensemble version label, so infer-plane requests
            # attribute per version like generate-plane ones do
            label = ",".join(f"{n}@v{v}" for n, v in sorted(active.items()))
            for c in ctxs:
                tr = getattr(c, "trace", None)
                if tr is not None and hasattr(tr, "annotate"):
                    tr.annotate("version", label)
        with self._stats_lock:
            for name, version in active.items():
                t = self._version_traffic.setdefault(
                    f"{name}@v{version}",
                    {"batches": 0, "rows": 0,
                     "interactive_requests": 0, "bulk_requests": 0})
                t["batches"] += 1
                t["rows"] += rows
                t["interactive_requests"] += interactive
                t["bulk_requests"] += bulk
        return ens.forward(batch)

    # --- admin plane ----------------------------------------------------------

    def load(self, name: str, version: Optional[int] = None, *,
             alias: Optional[str] = None, warm: bool = True,
             warm_example: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Load a store version and hot-swap it into an alias's ensemble."""
        alias = alias or self.default_alias
        with self._admin_lock:
            if version is None:
                version = self.store.latest_version(name)
                if version is None:
                    raise LifecycleError(
                        f"store has no published versions of {name!r}")
            manifest = self.store.manifest(name, version)   # raises StoreError
            rm = self._materialize(name, version, manifest)
            base = self._active.get(alias,
                                    self._active.get(self.default_alias, {}))
            old_version = self._active.get(alias, {}).get(name)
            new_map = dict(base)
            new_map[name] = version
            swap = self._apply_membership(
                alias, new_map, warm=warm, warm_example=warm_example)
            if old_version is not None and old_version != version:
                self._previous[(alias, name)] = old_version
            with self._stats_lock:
                self._counters["loads"] += 1
            return {"name": name, "version": version, "alias": alias,
                    "previous_version": old_version,
                    "manifest": manifest, **swap}

    def unload(self, name: str, version: Optional[int] = None) -> Dict[str, Any]:
        """Retire a loaded version, or the whole member when version is None.

        A version still active in any alias is refused (conflict) — swap or
        roll the alias first.  Removing the last member of an ensemble is
        refused for the same reason: the endpoint must keep serving.
        """
        with self._admin_lock:
            if version is not None:
                holders = [a for a, m in self._active.items()
                           if m.get(name) == version]
                holders += [f"engine:{a}"
                            for a, nv in self._engine_active.items()
                            if nv == (name, version)]
                if holders:
                    raise LifecycleError(
                        f"{name} v{version} is active in alias(es) "
                        f"{holders}; load another version or unload the "
                        f"member")
                self.registry.unregister(name, version)   # KeyError if absent
                with self._stats_lock:
                    self._counters["unloads"] += 1
                return {"name": name, "version": version, "unloaded": True}
            # whole-member retirement, every alias — validate every alias
            # BEFORE mutating any, so a refused unload changes nothing
            if not any(name in m for m in self._active.values()):
                raise LifecycleError(f"{name!r} is not an ensemble member")
            new_maps = {}
            for a, members in self._active.items():
                if name not in members:
                    continue
                new_map = {k: v for k, v in members.items() if k != name}
                if not new_map:
                    raise LifecycleError(
                        f"unloading {name!r} would empty alias {a!r}")
                new_maps[a] = new_map
            swaps = {a: self._apply_membership(a, new_map, warm=False)
                     for a, new_map in new_maps.items()}
            self.registry.unregister(name)
            self._previous = {k: v for k, v in self._previous.items()
                              if k[1] != name}
            with self._stats_lock:
                self._counters["unloads"] += 1
            return {"name": name, "unloaded": True, "aliases": swaps}

    def rollback(self, name: str, *,
                 alias: Optional[str] = None, warm: bool = True) -> Dict[str, Any]:
        """Swap an alias back to the member's previously active version."""
        alias = alias or self.default_alias
        with self._admin_lock:
            prev = self._previous.get((alias, name))
            if prev is None:
                raise LifecycleError(
                    f"no previous version of {name!r} recorded for alias "
                    f"{alias!r}")
            result = self.load(name, prev, alias=alias, warm=warm)
            with self._stats_lock:
                self._counters["rollbacks"] += 1
                self._counters["loads"] -= 1    # it was a rollback, not a load
            result["rolled_back_to"] = prev
            return result

    # --- generation-engine plane ----------------------------------------------

    def attach_generation(self, service) -> Any:
        """Wire a ``GenerationService``; engine versions then flow through
        this manager (load_engine / rollback_engine), under the manager's
        drain budget."""
        service.drain_timeout_s = self.drain_timeout_s
        self.generation = service
        return service

    def _require_generation(self):
        if self.generation is None:
            raise LifecycleError(
                "no generation service attached to this manager; "
                "engine lifecycle needs a scheduler-backed endpoint")
        return self.generation

    def load_engine(self, name: str, version: Optional[int] = None, *,
                    alias: Optional[str] = None,
                    warm: bool = True,
                    draft: Optional[str] = None,
                    draft_version: Optional[int] = None,
                    max_window: int = 4) -> Dict[str, Any]:
        """Materialize a store version (restore + hash verify) as an
        InferenceEngine and hot-swap it under an engine alias.  In-flight
        decode streams drain on the displaced engine before it is closed;
        new requests land on the new engine immediately.  ``warm``
        (default) pre-compiles the new engine's decode data path BEFORE
        the alias flips, so the swap never stalls live streams on jit
        compiles (mirrors the model plane's warm-before-publish).

        ``draft`` names a second store model to materialize as the
        proposer of a speculative pair: both checkpoints restore + hash
        verify, and the alias serves ONE ``SpeculativeEngine`` wrapping
        them — so canary/promote/demote/rollback move the pair as a unit
        and neither checkpoint is gc-eligible while the alias lives.
        ``max_window`` bounds the per-tick proposal window."""
        gen = self._require_generation()
        alias = alias or self.default_alias
        with self._admin_lock:
            if version is None:
                version = self.store.latest_version(name)
                if version is None:
                    raise LifecycleError(
                        f"store has no published versions of {name!r}")
            manifest = self.store.manifest(name, version)  # raises StoreError
            rm = self._materialize(name, version, manifest)
            engine = self._engine_factory(manifest, rm.model, rm.params)
            draft_nv: Optional[Tuple[str, int]] = None
            if draft is not None:
                if draft_version is None:
                    draft_version = self.store.latest_version(draft)
                    if draft_version is None:
                        raise LifecycleError(
                            f"store has no published versions of draft "
                            f"{draft!r}")
                dmanifest = self.store.manifest(draft, draft_version)
                drm = self._materialize(draft, draft_version, dmanifest)
                draft_engine = self._engine_factory(dmanifest, drm.model,
                                                    drm.params)
                try:
                    engine = SpeculativeEngine(engine, draft_engine,
                                               max_window=max_window)
                except ValueError as e:
                    raise LifecycleError(
                        f"incompatible speculative pair {name} v{version} "
                        f"+ {draft} v{draft_version}: {e}") from None
                draft_nv = (draft, draft_version)
            swap = gen.install(name, version, engine, alias=alias,
                               warm=warm)
            old = self._engine_active.get(alias)
            old_draft = self._engine_drafts.get(alias)
            self._engine_active[alias] = (name, version)
            if draft_nv is not None:
                self._engine_drafts[alias] = draft_nv
            else:
                self._engine_drafts.pop(alias, None)
            if old is not None and old != (name, version):
                self._engine_previous[alias] = old
                self._engine_prev_drafts[alias] = old_draft
            with self._stats_lock:
                self._counters["engine_loads"] += 1
            return {"name": name, "version": version,
                    "manifest": manifest,
                    "speculative": draft_nv is not None,
                    "draft": (f"{draft_nv[0]}@v{draft_nv[1]}"
                              if draft_nv is not None else None),
                    **swap}

    def rollback_engine(self, name: Optional[str] = None, *,
                        alias: Optional[str] = None,
                        warm: bool = True) -> Dict[str, Any]:
        """Swap an engine alias back to its previously active version."""
        alias = alias or self.default_alias
        with self._admin_lock:
            prev = self._engine_previous.get(alias)
            if prev is None:
                raise LifecycleError(
                    f"no previous engine recorded for alias {alias!r}")
            if name is not None and prev[0] != name:
                raise LifecycleError(
                    f"alias {alias!r} previously served engine "
                    f"{prev[0]!r} v{prev[1]}, not {name!r}")
            prev_draft = self._engine_prev_drafts.get(alias)
            result = self.load_engine(
                prev[0], prev[1], alias=alias, warm=warm,
                draft=prev_draft[0] if prev_draft is not None else None,
                draft_version=(prev_draft[1] if prev_draft is not None
                               else None))
            with self._stats_lock:
                self._counters["engine_rollbacks"] += 1
                self._counters["engine_loads"] -= 1   # rollback, not a load
            result["rolled_back_to"] = prev[1]
            return result

    def engine_version_label(self, alias: Optional[str] = None
                             ) -> Optional[str]:
        """``"name@vN"`` currently served under an engine alias, or None —
        the SLO controller's resolve callback."""
        nv = self._engine_active.get(alias or self.default_alias)
        return f"{nv[0]}@v{nv[1]}" if nv is not None else None

    def promote_engine(self, alias: str = "canary", *,
                       to_alias: Optional[str] = None) -> Dict[str, Any]:
        """Make ``alias``'s engine the ``to_alias`` (default: stable)
        engine — canary promotion.  A pointer flip, not a reload: both
        aliases share the already-warm live entry, so promotion costs no
        compile and truncates nothing (the displaced stable engine drains
        in-flight streams before closing).  The displaced version is
        recorded as ``to_alias``'s rollback target."""
        gen = self._require_generation()
        to_alias = to_alias or self.default_alias
        with self._admin_lock:
            src = self._engine_active.get(alias)
            if src is None:
                raise LifecycleError(
                    f"no engine under alias {alias!r} to promote")
            swap = gen.repoint(alias, to_alias)
            old = self._engine_active.get(to_alias)
            old_draft = self._engine_drafts.get(to_alias)
            self._engine_active[to_alias] = src
            src_draft = self._engine_drafts.get(alias)
            if src_draft is not None:
                self._engine_drafts[to_alias] = src_draft
            else:
                self._engine_drafts.pop(to_alias, None)
            if old is not None and old != src:
                self._engine_previous[to_alias] = old
                self._engine_prev_drafts[to_alias] = old_draft
            with self._stats_lock:
                self._counters["engine_promotes"] += 1
            return {"name": src[0], "version": src[1], "from_alias": alias,
                    "promoted": swap.get("changed", True), **swap}

    def demote_engine(self, alias: str = "canary", *,
                      to_alias: Optional[str] = None) -> Dict[str, Any]:
        """Point a misbehaving ``alias`` back at ``to_alias``'s (default:
        stable's) engine — canary auto-rollback.  The breaching engine
        drains its in-flight streams and closes once no alias references
        it; canary traffic lands on the stable engine immediately."""
        gen = self._require_generation()
        to_alias = to_alias or self.default_alias
        with self._admin_lock:
            src = self._engine_active.get(to_alias)
            if src is None:
                raise LifecycleError(
                    f"no engine under alias {to_alias!r} to demote "
                    f"{alias!r} onto")
            swap = gen.repoint(to_alias, alias)
            old = self._engine_active.get(alias)
            old_draft = self._engine_drafts.get(alias)
            self._engine_active[alias] = src
            src_draft = self._engine_drafts.get(to_alias)
            if src_draft is not None:
                self._engine_drafts[alias] = src_draft
            else:
                self._engine_drafts.pop(alias, None)
            if old is not None and old != src:
                self._engine_previous[alias] = old
                self._engine_prev_drafts[alias] = old_draft
            with self._stats_lock:
                self._counters["engine_demotes"] += 1
            return {"name": src[0], "version": src[1],
                    "demoted_from": f"{old[0]}@v{old[1]}" if old else None,
                    **swap}

    # --- retention GC ---------------------------------------------------------

    def gc(self, name: str, keep_last_n: int) -> Dict[str, Any]:
        """Apply keep-last-N retention to ``name``'s store versions.
        Versions referenced by ANY serving alias — ensemble or engine,
        active or recorded as a rollback target — are never deleted."""
        with self._admin_lock:
            protected = {m[name] for m in self._active.values()
                         if name in m}
            protected |= {v for (a, n), v in self._previous.items()
                          if n == name}
            protected |= {v for n, v in self._engine_active.values()
                          if n == name}
            protected |= {v for n, v in self._engine_previous.values()
                          if n == name}
            protected |= {v for n, v in self._engine_drafts.values()
                          if n == name}
            protected |= {nv[1] for nv in self._engine_prev_drafts.values()
                          if nv is not None and nv[0] == name}
            result = self.store.gc(name, keep_last_n, protected=protected)
            with self._stats_lock:
                self._counters["gc_runs"] += 1
            return result

    def bootstrap(self, names: Optional[List[str]] = None, *,
                  warm_example: Optional[Dict[str, Any]] = None) -> "ModelManager":
        """Load the latest store version of every named model (default: all
        models in the store) into the default alias — endpoint startup."""
        names = names if names is not None else self.store.names()
        if not names:
            raise LifecycleError("model store is empty; publish versions "
                                 "before serving from it")
        for name in names:
            self.load(name, alias=self.default_alias,
                      warm=warm_example is not None,
                      warm_example=warm_example)
        return self

    # --- internals ------------------------------------------------------------

    def _materialize(self, name: str, version: int,
                     manifest: Dict[str, Any]):
        """Restore+verify a version into the registry (idempotent)."""
        try:
            return self.registry.get(name, version)
        except KeyError:
            pass
        model, apply_fn, num_classes = self._factory(manifest)
        like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        if self.faults is not None:
            # "checkpoint_load": a corrupted/unreadable checkpoint —
            # surfaces like any store failure, BEFORE anything publishes
            try:
                self.faults.fire("checkpoint_load", name=name,
                                 version=version)
            except InjectedFault as e:
                raise LifecycleError(
                    f"checkpoint load failed for {name} v{version}: {e}"
                ) from e
        params, manifest = self.store.load(name, version, like)
        return self.registry.register(
            name, model, params, version=version,
            param_hash=manifest["param_hash"], apply=apply_fn,
            num_classes=num_classes)

    def _members_for(self, membership: Dict[str, int]) -> List[EnsembleMember]:
        members = []
        for name in sorted(membership):
            rm = self.registry.get(name, membership[name])
            members.append(EnsembleMember(
                name, rm.meta["apply"], rm.params,
                rm.meta.get("num_classes", 0)))
        return members

    def _apply_membership(self, alias: str, membership: Dict[str, int], *,
                          warm: bool,
                          warm_example: Optional[Dict[str, Any]] = None
                          ) -> Dict[str, Any]:
        members = self._members_for(membership)
        example = warm_example if warm_example is not None \
            else self._warm_example
        warm_batch = example if (warm and example is not None) else None
        ens = self._ensembles.get(alias)
        if ens is None:
            ens = Ensemble(members, max_batch=self.max_batch,
                           class_names=self.class_names)
            warm_s = ens.warm(warm_batch) if warm_batch is not None else 0.0
            swap = {"warm_s": warm_s, "drained": True,
                    "members": [m.name for m in members]}
            self._ensembles[alias] = ens
        else:
            swap = ens.set_members(members, warm_batch=warm_batch,
                                   drain_timeout=self.drain_timeout_s)
        self._active[alias] = membership
        with self._stats_lock:
            self._counters["swaps"] += 1
            self._warm_total_s += swap["warm_s"]
            self._last_warm_s = swap["warm_s"]
        return {"alias": alias, "warmed": warm_batch is not None,
                "warm_ms": 1e3 * swap["warm_s"], "drained": swap["drained"]}

    # --- introspection --------------------------------------------------------

    def status(self, name: str) -> Dict[str, Any]:
        """Store versions + manifests, loaded versions, and per-alias
        activity for one model — the GET /v1/models/{name} payload."""
        store_versions = self.store.versions(name)
        loaded = self.registry.versions(name)
        if not store_versions and not loaded:
            raise LifecycleError(f"unknown model {name!r}")
        active = {a: m[name] for a, m in self._active.items() if name in m}
        with self._stats_lock:
            traffic = {k: dict(v) for k, v in self._version_traffic.items()
                       if k.startswith(f"{name}@v")}
        return {
            "name": name,
            "versions": [self.store.manifest(name, v)
                         for v in store_versions],
            "loaded_versions": loaded,
            "active": active,
            "previous": {a: v for (a, n), v in self._previous.items()
                         if n == name},
            "engine_active": {a: v
                              for a, (n, v) in self._engine_active.items()
                              if n == name},
            "traffic": traffic,
        }

    def stats(self) -> Dict[str, Any]:
        with self._stats_lock:
            out: Dict[str, Any] = dict(self._counters)
            out["last_warm_ms"] = 1e3 * self._last_warm_s
            out["warm_total_ms"] = 1e3 * self._warm_total_s
            out["per_version"] = {k: dict(v)
                                  for k, v in self._version_traffic.items()}
        out["aliases"] = {a: dict(m) for a, m in self._active.items()}
        out["engine_aliases"] = {a: f"{n}@v{v}" for a, (n, v)
                                 in self._engine_active.items()}
        out["engine_drafts"] = {a: f"{n}@v{v}" for a, (n, v)
                                in self._engine_drafts.items()}
        return out
