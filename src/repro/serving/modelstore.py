"""Versioned on-disk model store with provenance manifests.

FlexServe's raison d'être (paper §1) is keeping model provenance and model
evolution under the operator's control in strict environments.  The store
is the durable half of that: every published version of a model lives in
its own directory with the checkpoint AND a manifest recording exactly
what it is and where it came from —

    <root>/<model_name>/
        v0001/
            step_0.ckpt       # msgpack(+zstd) checkpoint (training.checkpoint)
            manifest.json     # {name, version, config, param_hash, source,
                              #  created_at, ...}
        v0002/
            ...

Versions are immutable once published; ``publish`` allocates the next
number atomically via exclusive directory creation, and manifests are
written write-then-rename so concurrent readers never see a torn file.
``load`` re-hashes the restored leaves against the manifest so a corrupt
or swapped checkpoint is rejected before it can reach an endpoint.
"""

from __future__ import annotations

import os
import re
import shutil
import time
from datetime import datetime, timezone
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.training import checkpoint

_VDIR = re.compile(r"v(\d{4,})")
CKPT_FILE = "step_0.ckpt"
MANIFEST_FILE = "manifest.json"


class StoreError(RuntimeError):
    pass


class ModelStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    # --- layout ---------------------------------------------------------------

    def model_dir(self, name: str) -> str:
        if not re.fullmatch(r"[A-Za-z0-9._#-]+", name):
            raise StoreError(f"invalid model name {name!r}")
        return os.path.join(self.root, name)

    def version_dir(self, name: str, version: int) -> str:
        return os.path.join(self.model_dir(name), f"v{version:04d}")

    def names(self) -> List[str]:
        if not os.path.isdir(self.root):
            return []
        return sorted(d for d in os.listdir(self.root)
                      if os.path.isdir(os.path.join(self.root, d)))

    def versions(self, name: str) -> List[int]:
        mdir = self.model_dir(name)
        if not os.path.isdir(mdir):
            return []
        out = []
        for d in os.listdir(mdir):
            m = _VDIR.fullmatch(d)
            # only versions whose manifest landed count as published
            if m and os.path.exists(os.path.join(mdir, d, MANIFEST_FILE)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_version(self, name: str) -> Optional[int]:
        versions = self.versions(name)
        return versions[-1] if versions else None

    # --- publish / read -------------------------------------------------------

    def publish(self, name: str, params, *, config: str, source: str = "",
                meta: Optional[Dict[str, Any]] = None) -> int:
        """Write ``params`` as the next version of ``name``; returns it.

        The version directory is claimed with an exclusive mkdir, so two
        concurrent publishers can never collide on a number; the manifest
        is written LAST, making it the commit record — a crashed publish
        leaves an unlisted directory, not a half-readable version.
        """
        os.makedirs(self.model_dir(name), exist_ok=True)
        version = (self.latest_version(name) or 0) + 1
        for _ in range(100):
            vdir = self.version_dir(name, version)
            try:
                os.mkdir(vdir)
                break
            except FileExistsError:
                version += 1
        else:
            raise StoreError(f"cannot allocate a version for {name!r}")
        checkpoint.save(os.path.join(vdir, CKPT_FILE), params)
        manifest = {
            "name": name,
            "version": version,
            "config": config,
            "param_hash": checkpoint.param_hash(params),
            "source": source,
            "created_at": datetime.now(timezone.utc).isoformat(),
            "created_at_unix": time.time(),
            **(meta or {}),
        }
        checkpoint.write_manifest(os.path.join(vdir, MANIFEST_FILE),
                                  manifest)
        return version

    def manifest(self, name: str, version: int) -> Dict[str, Any]:
        path = os.path.join(self.version_dir(name, version), MANIFEST_FILE)
        if not os.path.exists(path):
            raise StoreError(
                f"no published version {version} of {name!r}; "
                f"available: {self.versions(name)}")
        return checkpoint.read_manifest(path)

    def manifests(self, name: str) -> List[Dict[str, Any]]:
        return [self.manifest(name, v) for v in self.versions(name)]

    def load(self, name: str, version: int, like_tree, *,
             verify: bool = True) -> Tuple[Any, Dict[str, Any]]:
        """Restore a version's params into ``like_tree``'s structure.

        With ``verify`` (default), the restored leaves are re-hashed and
        checked against the manifest's ``param_hash`` — provenance is only
        as good as the bytes actually served.
        """
        manifest = self.manifest(name, version)
        path = os.path.join(self.version_dir(name, version), CKPT_FILE)
        tree, _meta = checkpoint.restore(path, like_tree)
        if verify:
            got = checkpoint.param_hash(tree)
            if got != manifest["param_hash"]:
                raise StoreError(
                    f"{name} v{version}: param hash mismatch "
                    f"(manifest {manifest['param_hash'][:12]}…, "
                    f"checkpoint {got[:12]}…) — refusing to serve")
        return tree, manifest

    # --- retention ------------------------------------------------------------

    def gc(self, name: str, keep_last_n: int, *,
           protected: Iterable[int] = ()) -> Dict[str, Any]:
        """Delete published versions beyond the newest ``keep_last_n``.

        ``protected`` versions (the lifecycle manager passes everything a
        serving alias references) are NEVER deleted regardless of age —
        retention must not be able to pull a version out from under live
        traffic or a rollback.  Versions are immutable, so deletion is the
        only mutation the store ever performs; a version number is never
        reused afterwards (publish allocates past the highest survivor).
        """
        if keep_last_n < 1:
            raise StoreError(f"keep_last_n must be >= 1, got {keep_last_n}")
        versions = self.versions(name)
        if not versions:
            raise StoreError(f"store has no published versions of {name!r}")
        protected = set(protected)
        keep = set(versions[-keep_last_n:]) | protected
        deleted = []
        for v in versions:
            if v in keep:
                continue
            shutil.rmtree(self.version_dir(name, v))
            deleted.append(v)
        return {"name": name, "deleted": deleted,
                "kept": [v for v in versions if v in keep],
                "protected": sorted(protected & set(versions))}
