"""repro: FlexServe-JAX - multi-pod JAX serving framework with flexible
batching and multi-model ensembles (reproduction of Verenich et al. 2020)."""

__version__ = "0.1.0"
