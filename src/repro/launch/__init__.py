from repro.launch.mesh import (
    data_axis_size, make_local_mesh, make_production_mesh, mesh_num_chips,
    model_axis_size)

__all__ = [
    "make_production_mesh", "make_local_mesh", "mesh_num_chips",
    "data_axis_size", "model_axis_size",
]
