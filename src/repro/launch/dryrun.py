import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST run before any jax import (jax locks the device
count at first init); 512 host devices back both the 256-chip single-pod
mesh and the 512-chip multi-pod mesh.

Per combination this driver:
  1. builds the model + ShapeDtypeStruct inputs (no allocation),
  2. assigns in_shardings (params HSDP, batch over data, caches per shape),
  3. ``jit(step).lower(...).compile()`` under the target mesh,
  4. records memory_analysis / cost_analysis / per-collective bytes parsed
     from the optimized HLO into results/dryrun/<arch>.<shape>.<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import functools
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import opt as opt_flags
from repro.configs import ASSIGNED_ARCHS, get_config, get_shape, SHAPES
from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.launch import shardings as shd
from repro.launch.mesh import make_production_mesh
from repro.models.build import build_model
from repro.sharding import use_mesh
from repro.training import optimizer
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import make_train_step

# long-context policy (DESIGN.md §5): whisper skips long_500k; dense /
# full-attention archs run it through the sliding-window serving variant.
LONG_SKIP = {"whisper-base"}
LONG_WINDOW = {
    "yi-9b": 4096, "command-r-plus-104b": 4096, "mistral-large-123b": 4096,
    "qwen3-moe-235b-a22b": 4096, "llama-3.2-vision-11b": 4096,
    # native/window-free long-context archs:
    "h2o-danube-1.8b": None,      # native SWA already in config
    "rwkv6-1.6b": None, "zamba2-2.7b": None, "deepseek-v3-671b": None,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples by summing)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text.

    Builds a symbol table of instruction result sizes, then for each
    collective sums the sizes of its named operands."""
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = _type_bytes(m.group(2))
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                kind = c
                break
        if kind is None:
            continue
        # operand names inside the call parens
        args = line[line.index(op + "(") + len(op) + 1:]
        operands = re.findall(r"%[\w.\-]+", args)
        nbytes = sum(sizes.get(o, 0) for o in operands)
        if nbytes == 0:                     # fallback: result size
            nbytes = _type_bytes(m.group(2))
        out[kind] += nbytes
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# Step construction
# ---------------------------------------------------------------------------


def build_step(arch: str, shape_name: str, *, remat: bool = True,
               grad_accum: int = 1,
               window_override: Optional[int] = "auto"):
    """Returns (step_fn, args_sds tuple, in_shardings tuple, meta)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = build_model(cfg)
    kind = shape.kind
    window = None
    if window_override == "auto":
        if shape_name == "long_500k":
            window = LONG_WINDOW.get(arch)
    else:
        window = window_override

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch_sds = model.input_specs(shape)

    if kind == "train":
        opt_cfg = OptimizerConfig(
            moment_dtype="bfloat16"
            if opt_flags.enabled("opt_bf16_moments") else None)
        import jax.numpy as _jnp
        step = make_train_step(
            model, opt_cfg, remat=remat, grad_accum=grad_accum,
            accum_dtype=_jnp.bfloat16
            if opt_flags.enabled("opt_bf16_moments") else None)
        opt_sds = jax.eval_shape(
            lambda ps: optimizer.init(ps, opt_cfg.moment_dtype), params_sds)
        args = (params_sds, opt_sds, batch_sds)
        meta = {"step": "train_step"}
        return step, args, meta, model, cfg, shape

    if kind == "prefill":
        def step(params, batch, state):
            return model.prefill(params, batch, state)
        state_sds = model.state_specs(shape.global_batch, shape.seq_len)
        args = (params_sds, batch_sds, state_sds)
        return step, args, {"step": "prefill_step"}, model, cfg, shape

    # decode: ONE token against a cache of seq_len
    def step(params, token, state):
        if window is not None:
            return model.decode(params, token, state, window=window)
        return model.decode(params, token, state)

    state_sds = model.state_specs(shape.global_batch, shape.seq_len,
                                  window=window)
    args = (params_sds, batch_sds["token"], state_sds)
    meta = {"step": "serve_step", "window": window}
    return step, args, meta, model, cfg, shape


def shardings_for(args, kind: str, cfg: ModelConfig, mesh,
                  shape: InputShape):
    from jax.sharding import NamedSharding, PartitionSpec as P
    params_shd = shd.param_shardings_for(args[0], mesh)
    if kind == "train":
        opt_shd = shd.opt_state_shardings(args[0], mesh)
        batch_shd = shd.batch_shardings(mesh, args[2])
        return (params_shd, opt_shd, batch_shd)
    if kind == "prefill":
        batch_shd = shd.batch_shardings(mesh, args[1])
        state_shd = shd.state_shardings(args[2], cfg, mesh)
        return (params_shd, batch_shd, state_shd)
    token_shd = NamedSharding(
        mesh, shd.batch_spec(mesh, shape.global_batch, 1))
    state_shd = shd.state_shardings(args[2], cfg, mesh)
    return (params_shd, token_shd, state_shd)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: Optional[str] = None, remat: bool = True,
            grad_accum: int = 1,
            window_override="auto", verbose: bool = True) -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if shape_name == "long_500k" and arch in LONG_SKIP:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "skipped",
                  "reason": "enc-dec full attention; see DESIGN.md §5"}
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            fn = os.path.join(out_dir,
                              f"{arch}.{shape_name}.{mesh_name}.json")
            with open(fn, "w") as f:
                json.dump(result, f, indent=1)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIPPED "
              f"({result['reason']})")
        return result
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    step, args, meta, model, cfg, shape = build_step(
        arch, shape_name, remat=remat, grad_accum=grad_accum,
        window_override=window_override)
    in_shd = shardings_for(args, shape.kind, cfg, mesh, shape)

    # donate the mutable buffers (train: params+opt; serve: the KV cache)
    # so XLA aliases them in place — production memory behavior.
    donate = {"train": (0, 1), "prefill": (), "decode": (2,)}[shape.kind]
    with use_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_shd, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # loop-aware per-device costs: multiply while-body costs by trip counts
    # (cost_analysis counts scan bodies ONCE — see analysis/hlo_costs.py)
    from repro.analysis.hlo_costs import analyze_hlo
    la = analyze_hlo(hlo)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", **meta,
        "opt_flags": opt_flags.all_flags(),
        "grad_accum": grad_accum,
        "n_devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {
            "flops": la["flops"],
            "bytes_accessed": la["memory_bytes"],
            "xla_flops_noloop": cost.get("flops"),
            "xla_bytes_noloop": cost.get("bytes accessed"),
        },
        "collectives": la["collectives"],
        "collectives_noloop": coll,
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
        "hlo_lines": hlo.count("\n"),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"flops={result['cost']['flops']:.3e} "
              f"coll={la['collectives']['total_bytes']:.3e}B "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        print(f"  memory_analysis: {result['memory']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}.{shape_name}.{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--opts", default="none",
                    help="'none' (paper-faithful baseline), 'all', or a "
                         "comma-list of repro.opt flags")
    args = ap.parse_args(argv)
    opt_flags.set_flags(**opt_flags.parse(args.opts))

    combos = []
    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ([False, True] if args.both_meshes
              else [bool(args.multi_pod)])
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    failures = 0
    for a, s, mp in combos:
        try:
            run_one(a, s, multi_pod=mp, out_dir=args.out,
                    remat=not args.no_remat, grad_accum=args.grad_accum)
        except Exception:
            failures += 1
            print(f"[dryrun] {a} x {s} x "
                  f"{'pod2x16x16' if mp else 'pod16x16'}: FAILED")
            traceback.print_exc()
    print(f"[dryrun] done: {len(combos) - failures}/{len(combos)} OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
