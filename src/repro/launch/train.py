"""Training launcher.

CPU/container mode trains a REDUCED variant of the selected arch on the
synthetic pipeline (the end-to-end example driver); on a real TPU pod the
same entry point takes --full and the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --steps 200 --seq-len 64 --batch 16
"""

from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_for_smoke
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.build import build_model
from repro.sharding import use_mesh
from repro.training import (
    DataConfig, OptimizerConfig, SyntheticLM, Trainer, TrainerConfig)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b",
                    choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — TPU pods only")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg)
    mesh = (make_production_mesh(multi_pod=args.multi_pod) if args.full
            else None)

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, num_dialects=1))
    opt = OptimizerConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                          total_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, log_every=args.log_every,
                         ckpt_dir=args.ckpt_dir,
                         grad_accum=args.grad_accum)

    def run():
        trainer = Trainer(model, opt, tcfg, rng=jax.random.PRNGKey(0))
        hist = trainer.fit(iter(data))
        return hist

    if mesh is not None:
        with use_mesh(mesh):
            hist = run()
    else:
        hist = run()

    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(hist, f, indent=1)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[train] {args.arch}: loss {first:.4f} -> {last:.4f} over "
          f"{args.steps} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
