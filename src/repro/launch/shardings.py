"""Sharding assignment for dry-run/launch inputs: params, optimizer state,
decode caches, and data batches.

Parameter specs come from repro.sharding's leaf-name rules (HSDP: d_model
dim -> data axis, head/ff/vocab dim -> model axis, expert dim -> data).

Decode-state specs are chosen per shape:
  * batch dim -> ("pod","data") when divisible (decode_32k, prefill_32k);
  * kv-head dim -> "model" when there are >= model_size kv heads;
  * otherwise the KV *sequence* dim -> "model";
  * long-context batch=1 -> sequence over ALL chips ("data","model").
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axis_size, model_axis_size
from repro.sharding import param_specs
from repro.training.optimizer import OptState


def _batch_axes(mesh: Mesh):
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on any dim whose size isn't divisible by its mesh
    axes (jit in_shardings require exact divisibility — e.g. whisper's
    vocab 51865 can't split 16 ways)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        n = _axis_size(mesh, entry)
        out.append(entry if (n > 1 and dim % n == 0) or n == 1 else None)
    return P(*out)


def sanitize_tree(sds_tree, spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda sds, spec: sanitize_spec(spec, sds.shape, mesh),
        sds_tree, spec_tree)


def batch_spec(mesh: Mesh, batch: int, rank: int) -> P:
    axes = _batch_axes(mesh)
    n = data_axis_size(mesh)
    if batch % n == 0 and batch >= n:
        lead = axes if len(axes) > 1 else axes[0]
        return P(lead, *([None] * (rank - 1)))
    return P(*([None] * rank))


def batch_shardings(mesh: Mesh, batch_sds: dict) -> dict:
    out = {}
    for k, v in batch_sds.items():
        b = v.shape[0] if v.shape else 1
        out[k] = NamedSharding(mesh, batch_spec(mesh, b, len(v.shape)))
    return out


# --- decode / prefill state ---------------------------------------------------

_SEQ_CACHE_NAMES = {"k", "v", "xk", "xv", "shared_k", "shared_v"}
_LATENT_CACHE_NAMES = {"ckv", "krope"}


def _leaf_name(path) -> str:
    for part in reversed(path):
        key = getattr(part, "key", None)
        if isinstance(key, str):
            return key
    return ""


def state_specs(state_sds, cfg: ModelConfig, mesh: Mesh):
    dsize = data_axis_size(mesh)
    msize = model_axis_size(mesh)
    batch_lead = (("pod", "data") if "pod" in mesh.axis_names else "data")

    def one(path, leaf):
        name = _leaf_name(path)
        rank = len(leaf.shape)
        spec = [None] * rank
        if name == "length":
            return P(*spec)
        if name in _SEQ_CACHE_NAMES and rank >= 4:
            # (..., B, S, K, hd)
            b_ax, s_ax, k_ax = rank - 4, rank - 3, rank - 2
            B, K = leaf.shape[b_ax], leaf.shape[k_ax]
            if B % dsize == 0 and B >= dsize:
                spec[b_ax] = batch_lead
                if K % msize == 0 and K >= msize:
                    spec[k_ax] = "model"
                elif leaf.shape[s_ax] % msize == 0:
                    spec[s_ax] = "model"
            else:  # batch=1 long-context: shard seq over ALL chips
                if leaf.shape[s_ax] % (dsize * msize) == 0:
                    spec[s_ax] = (("pod", "data", "model")
                                  if "pod" in mesh.axis_names
                                  else ("data", "model"))
            return P(*spec)
        if name in _LATENT_CACHE_NAMES and rank >= 3:
            # (L, B, S, C)
            b_ax, s_ax = rank - 3, rank - 2
            B = leaf.shape[b_ax]
            if B % dsize == 0 and B >= dsize:
                spec[b_ax] = batch_lead
                if leaf.shape[s_ax] % msize == 0:
                    spec[s_ax] = "model"
            elif leaf.shape[s_ax] % (dsize * msize) == 0:
                spec[s_ax] = (("pod", "data", "model")
                              if "pod" in mesh.axis_names
                              else ("data", "model"))
            return P(*spec)
        if name == "wkv" and rank == 5:            # (L,B,H,N,N)
            if leaf.shape[1] % dsize == 0:
                spec[1] = batch_lead
            if leaf.shape[2] % msize == 0:
                spec[2] = "model"
            return P(*spec)
        if name in ("tm_shift", "cm_shift") and rank == 3:   # (L,B,D)
            if leaf.shape[1] % dsize == 0:
                spec[1] = batch_lead
            if leaf.shape[2] % msize == 0:
                spec[2] = "model"
            return P(*spec)
        if name == "conv" and rank == 4:           # (L,B,K-1,C)
            if leaf.shape[1] % dsize == 0:
                spec[1] = batch_lead
            if leaf.shape[3] % msize == 0:
                spec[3] = "model"
            return P(*spec)
        if name == "ssd" and rank == 5:            # (L,B,H,P,N)
            if leaf.shape[1] % dsize == 0:
                spec[1] = batch_lead
            if leaf.shape[2] % msize == 0:
                spec[2] = "model"
            return P(*spec)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, state_sds)


def state_shardings(state_sds, cfg: ModelConfig, mesh: Mesh):
    specs = sanitize_tree(state_sds, state_specs(state_sds, cfg, mesh), mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def param_shardings_for(params_sds, mesh: Mesh):
    specs = sanitize_tree(params_sds, param_specs(params_sds, mesh), mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def opt_state_shardings(params_sds, mesh: Mesh) -> Any:
    pspec = param_shardings_for(params_sds, mesh)
    return OptState(
        step=NamedSharding(mesh, P()),
        mu=pspec,
        nu=pspec,
    )
