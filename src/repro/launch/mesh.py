"""Production meshes (TPU v5e).

Defined as FUNCTIONS so importing this module never touches jax device
state — critical because the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count BEFORE first jax init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_num_chips(mesh) -> int:
    return mesh.devices.size


def data_axis_size(mesh) -> int:
    size = mesh.shape.get("data", 1)
    if "pod" in mesh.axis_names:
        size *= mesh.shape["pod"]
    return size


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
