"""Serving launcher: deploy one or more archs behind a FlexServe endpoint.

CPU/container mode serves REDUCED variants (the paper's kind of
deployment, runnable here); --full targets the production mesh on TPU.

  PYTHONPATH=src python -m repro.launch.serve \
      --ensemble yi-9b yi-9b h2o-danube-1.8b --port 8000

With ``--model-store DIR`` the endpoint is store-backed: member params are
published to (or loaded from) a versioned on-disk model store with
provenance manifests, and the server exposes the lifecycle admin surface
(GET /v1/models/{name}, POST .../load /unload /rollback /gc, plus
POST /v1/engines/{name}/load|rollback for the generation engine) for hot
swaps under traffic.  /v1/generate supports token streaming
(``"stream": true``) and per-request sampling params.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_for_smoke
from repro.core import (Ensemble, EnsembleMember, InferenceEngine,
                        ModelRegistry, SpeculativeEngine)
from repro.models.build import build_model
from repro.core.faults import FaultInjector
from repro.serving import (FlexServeApp, FlexServeServer, ModelManager,
                           ModelStore)


def build_app(arch_names, *, num_classes: int = 16, max_len: int = 256,
              max_batch: int = 8, full: bool = False,
              seed: int = 0, num_slots: int = 4,
              max_queue: int = 64, generate_token_budget=None,
              default_deadline_ms=None, trace: bool = True,
              flight_recorder_size: int = 256,
              profile_dir=None, slo_config=None,
              client_weights=None, draft_model=None,
              draft_layers=None, spec_window: int = 4,
              replicas: int = 1, fault_config=None) -> FlexServeApp:
    registry = ModelRegistry()
    members = []
    engine = None
    for i, name in enumerate(arch_names):
        cfg = get_config(name)
        if not full:
            cfg = reduce_for_smoke(cfg)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed + i))
        reg_name = f"{name}#{i}"
        registry.register(reg_name, model, params)

        def apply(p, batch, _m=model, _c=num_classes):
            # classification readout: last-position logits over C classes
            return _m.forward(p, batch)[:, -1, :_c]

        members.append(EnsembleMember(reg_name, apply, params, num_classes))
        if engine is None and cfg.family in ("dense", "moe", "ssm",
                                             "hybrid"):
            engine = InferenceEngine(model, params, max_len=max_len,
                                     max_batch=max_batch)
    if engine is not None and draft_model is not None:
        # speculative pair: a (usually shallower) draft proposes, the
        # target verifies — seeded output stays byte-identical either way
        dcfg = get_config(draft_model)
        if not full:
            dcfg = reduce_for_smoke(dcfg)
        if draft_layers:
            dcfg = dataclasses.replace(dcfg, num_layers=int(draft_layers))
        dmodel = build_model(dcfg)
        dparams = dmodel.init(jax.random.PRNGKey(seed + 1000))
        engine = SpeculativeEngine(
            engine,
            InferenceEngine(dmodel, dparams, max_len=max_len,
                            max_batch=max_batch),
            max_window=spec_window)
        print(f"[serve] speculative decoding: draft {draft_model} "
              f"({dcfg.num_layers} layers) proposing up to "
              f"{engine.max_window} tokens/tick")
    ensemble = Ensemble(members, max_batch=max_batch)
    return FlexServeApp(registry, ensemble, engine, num_slots=num_slots,
                        max_queue=max_queue,
                        generate_token_budget=generate_token_budget,
                        default_deadline_ms=default_deadline_ms,
                        trace=trace,
                        flight_recorder_size=flight_recorder_size,
                        profile_dir=profile_dir, slo_policies=slo_config,
                        client_weights=client_weights,
                        replicas=replicas, fault_config=fault_config)


def build_store_app(arch_names, store_dir: str, *, num_classes: int = 16,
                    max_len: int = 256, max_batch: int = 8,
                    full: bool = False, seed: int = 0,
                    num_slots: int = 4, max_queue: int = 64,
                    generate_token_budget=None,
                    default_deadline_ms=None, trace: bool = True,
                    flight_recorder_size: int = 256,
                    profile_dir=None, slo_config=None,
                    client_weights=None, draft_model=None,
                    draft_layers=None, spec_window: int = 4,
                    replicas: int = 1, fault_config=None
                    ) -> FlexServeApp:
    """Store-backed startup: seed the store on first run, then serve the
    LATEST published version of every member through a ModelManager.  The
    generation engine is ALSO store-versioned: the first decode-capable
    member is loaded through the manager's engine plane, so it can be
    hot-swapped / rolled back under live streaming traffic."""
    store = ModelStore(store_dir)
    member_names = []
    engine_member = None
    for i, name in enumerate(arch_names):
        reg_name = f"{name}#{i}"
        member_names.append(reg_name)
        cfg = get_config(name)
        if not full:
            cfg = reduce_for_smoke(cfg)
        if store.latest_version(reg_name) is None:
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(seed + i))
            v = store.publish(reg_name, params, config=name,
                              source=cfg.source,
                              meta={"reduced": not full,
                                    "num_classes": num_classes,
                                    "init_seed": seed + i,
                                    "max_len": max_len,
                                    "max_batch": max_batch})
            print(f"[serve] published {reg_name} v{v} to {store_dir}")
        if engine_member is None and cfg.family in ("dense", "moe", "ssm",
                                                    "hybrid"):
            engine_member = reg_name
    # one injector shared end-to-end: checkpoint loads (manager), decode
    # drivers + replica monitor (pool), and the stream writer (handler)
    # all draw from the same deterministic schedule
    faults = FaultInjector.load(fault_config)
    manager = ModelManager(store, max_batch=max_batch, faults=faults)
    manager.bootstrap(member_names)
    app = FlexServeApp(manager=manager, num_slots=num_slots,
                       max_queue=max_queue,
                       generate_token_budget=generate_token_budget,
                       default_deadline_ms=default_deadline_ms,
                       trace=trace,
                       flight_recorder_size=flight_recorder_size,
                       profile_dir=profile_dir, slo_policies=slo_config,
                       client_weights=client_weights,
                       replicas=replicas, fault_config=faults)
    if engine_member is not None and app.generation is not None:
        draft_member = None
        if draft_model is not None:
            # publish the draft checkpoint as its own store version (its
            # manifest records the truncated depth) so the speculative
            # pair rides the normal engine lifecycle: load / canary /
            # promote / rollback move target+draft as one unit
            draft_member = f"{draft_model}#draft"
            if store.latest_version(draft_member) is None:
                dcfg = get_config(draft_model)
                if not full:
                    dcfg = reduce_for_smoke(dcfg)
                if draft_layers:
                    dcfg = dataclasses.replace(
                        dcfg, num_layers=int(draft_layers))
                dmodel = build_model(dcfg)
                dparams = dmodel.init(jax.random.PRNGKey(seed + 1000))
                v = store.publish(draft_member, dparams, config=draft_model,
                                  source=dcfg.source,
                                  meta={"reduced": not full,
                                        "num_classes": num_classes,
                                        "num_layers": dcfg.num_layers,
                                        "init_seed": seed + 1000,
                                        "max_len": max_len,
                                        "max_batch": max_batch})
                print(f"[serve] published draft {draft_member} v{v}")
        res = manager.load_engine(engine_member, draft=draft_member,
                                  max_window=spec_window)
        print(f"[serve] generation engine {res['engine']} "
              f"(alias {res['alias']})"
              + (f" + draft {res['draft']}" if res.get("draft") else ""))
    return app


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ensemble", nargs="+", default=["yi-9b"],
                    choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--num-classes", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--num-slots", type=int, default=4,
                    help="continuous-batching decode slots per engine")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission budget (rows) for the infer plane; "
                         "excess load is shed as 429 + Retry-After")
    ap.add_argument("--generate-token-budget", type=int, default=None,
                    help="generate-plane admission budget in TOKEN units "
                         "(prompt + requested max_new_tokens per request; "
                         "default 32 * max-queue)")
    ap.add_argument("--default-deadline-ms", type=float, default=None,
                    help="deadline applied to requests that don't carry "
                         "one; past-deadline requests drop as 504 before "
                         "costing a forward pass")
    ap.add_argument("--model-store", default=None, metavar="DIR",
                    help="versioned model store directory; enables the "
                         "lifecycle admin API and hot swaps")
    ap.add_argument("--no-trace", action="store_true",
                    help="disable per-request tracing + the flight "
                         "recorder (GET /v1/trace/{id} 404s)")
    ap.add_argument("--flight-recorder-size", type=int, default=256,
                    help="completed request timelines kept queryable "
                         "via GET /v1/trace/{id}")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="enable POST /v1/debug/profile; captures land "
                         "under this directory")
    ap.add_argument("--slo-config", default=None, metavar="FILE",
                    help="JSON SLO policy file ({'policies': [...]}); "
                         "enables the SLO autopilot: windowed burn-rate "
                         "evaluation with automatic canary promotion / "
                         "rollback, auditable at GET /v1/slo")
    ap.add_argument("--draft-model", default=None, metavar="ARCH",
                    choices=list(ASSIGNED_ARCHS),
                    help="enable speculative decoding: serve this arch as "
                         "the draft proposer (usually with --draft-layers "
                         "to truncate its depth); seeded outputs stay "
                         "byte-identical to non-speculative decoding, and "
                         "requests opt out per-call with "
                         "\"speculation\": false")
    ap.add_argument("--draft-layers", type=int, default=None,
                    help="truncate the draft model to this many layers "
                         "(a shallow draft is what makes proposing cheap)")
    ap.add_argument("--spec-window", type=int, default=4,
                    help="max draft tokens proposed per decode tick; the "
                         "scheduler adapts the live window to measured "
                         "acceptance")
    ap.add_argument("--replicas", type=int, default=1,
                    help="generate-plane scheduler replicas behind the "
                         "endpoint; >1 enables the health-checked replica "
                         "pool with automatic cordon/restart and "
                         "transparent stream failover (GET /v1/replicas, "
                         "POST /v1/replicas/{id}/cordon|uncordon)")
    ap.add_argument("--fault-config", default=None, metavar="FILE",
                    help="JSON fault schedule ({'faults': [...]}) for "
                         "deterministic chaos drills: inject raises/"
                         "stalls/drops at named sites (engine_step, "
                         "decode_tick, prefill, engine_install, "
                         "checkpoint_load, socket_drop, replica_kill)")
    ap.add_argument("--client-weight", action="append", default=None,
                    metavar="TAG=W",
                    help="per-client-tag fair-share weight (repeatable); "
                         "any weight enables weighted admission quotas + "
                         "weighted fair dequeue on the generate plane "
                         "(unlisted tags weigh 1.0)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    client_weights = None
    if args.client_weight:
        client_weights = {}
        for spec in args.client_weight:
            tag, sep, w = spec.partition("=")
            if not sep or not tag:
                ap.error(f"--client-weight needs TAG=WEIGHT, got {spec!r}")
            try:
                client_weights[tag] = float(w)
            except ValueError:
                ap.error(f"--client-weight {spec!r}: weight must be a "
                         f"number")

    kw = dict(num_classes=args.num_classes, max_len=args.max_len,
              max_batch=args.max_batch, full=args.full,
              num_slots=args.num_slots, max_queue=args.max_queue,
              generate_token_budget=args.generate_token_budget,
              default_deadline_ms=args.default_deadline_ms,
              trace=not args.no_trace,
              flight_recorder_size=args.flight_recorder_size,
              profile_dir=args.profile_dir, slo_config=args.slo_config,
              client_weights=client_weights, draft_model=args.draft_model,
              draft_layers=args.draft_layers, spec_window=args.spec_window,
              replicas=args.replicas, fault_config=args.fault_config)
    if args.model_store:
        app = build_store_app(args.ensemble, args.model_store, **kw)
    else:
        app = build_app(args.ensemble, **kw)
    if (app.generation is not None and app.generation.ready
            and app.manager is None):
        # pre-compile the decode data path (fused decode step, batched-
        # prefill buckets, slot scatter) so the first live streams never
        # pay compile latency.  Store-backed boots skip this: the
        # manager's load_engine already warmed before flipping the alias.
        warm_s = app.generation.entry_for().service.warm()
        print(f"[serve] decode path warm in {warm_s:.1f}s")
    if args.replicas > 1:
        print(f"[serve] replica pool: {args.replicas} decode replicas "
              f"(health-checked; GET /v1/replicas)")
    if args.fault_config:
        print(f"[serve] chaos: fault schedule armed from "
              f"{args.fault_config}")
    server = FlexServeServer(app, host=args.host, port=args.port)
    host, port = server.address
    print(f"[serve] FlexServe endpoint on http://{host}:{port} — "
          f"{len(app.registry)} model(s): {app.registry.names()}")
    print("[serve] routes: GET /health /healthz /metrics[?format="
          "prometheus] /v1/trace/{id} /v1/traces /v1/usage /v1/slo "
          "/v1/models /v1/models/{name} /v1/engines; POST /v1/infer "
          "/v1/detect /v1/generate (+\"stream\": true for token streaming)"
          + (" /v1/debug/profile" if args.profile_dir else "")
          + (" /v1/models/{name}/load|unload|rollback|gc "
             "/v1/engines/{name}/load|rollback"
             if app.manager else ""))
    if app.slo is not None:
        print(f"[serve] SLO autopilot: "
              f"{app.slo.stats()['policies']} policy(ies) from "
              f"{args.slo_config} — decisions audit at GET /v1/slo")
    try:
        server.httpd.serve_forever()
    except KeyboardInterrupt:
        print("\n[serve] shutting down")
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
