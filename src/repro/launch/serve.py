"""Serving launcher: deploy one or more archs behind a FlexServe endpoint.

CPU/container mode serves REDUCED variants (the paper's kind of
deployment, runnable here); --full targets the production mesh on TPU.

  PYTHONPATH=src python -m repro.launch.serve \
      --ensemble yi-9b yi-9b h2o-danube-1.8b --port 8000

With ``--model-store DIR`` the endpoint is store-backed: member params are
published to (or loaded from) a versioned on-disk model store with
provenance manifests, and the server exposes the lifecycle admin surface
(GET /v1/models/{name}, POST .../load /unload /rollback) for hot swaps
under traffic.
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_for_smoke
from repro.core import (Ensemble, EnsembleMember, InferenceEngine,
                        ModelRegistry)
from repro.models.build import build_model
from repro.serving import (FlexServeApp, FlexServeServer, ModelManager,
                           ModelStore)


def _build_engine(arch_names, *, max_len: int, max_batch: int,
                  full: bool, seed: int):
    for i, name in enumerate(arch_names):
        cfg = get_config(name)
        if not full:
            cfg = reduce_for_smoke(cfg)
        if cfg.family in ("dense", "moe", "ssm", "hybrid"):
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(seed + i))
            return InferenceEngine(model, params, max_len=max_len,
                                   max_batch=max_batch)
    return None


def build_app(arch_names, *, num_classes: int = 16, max_len: int = 256,
              max_batch: int = 8, full: bool = False,
              seed: int = 0) -> FlexServeApp:
    registry = ModelRegistry()
    members = []
    engine = None
    for i, name in enumerate(arch_names):
        cfg = get_config(name)
        if not full:
            cfg = reduce_for_smoke(cfg)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed + i))
        reg_name = f"{name}#{i}"
        registry.register(reg_name, model, params)

        def apply(p, batch, _m=model, _c=num_classes):
            # classification readout: last-position logits over C classes
            return _m.forward(p, batch)[:, -1, :_c]

        members.append(EnsembleMember(reg_name, apply, params, num_classes))
        if engine is None and cfg.family in ("dense", "moe", "ssm",
                                             "hybrid"):
            engine = InferenceEngine(model, params, max_len=max_len,
                                     max_batch=max_batch)
    ensemble = Ensemble(members, max_batch=max_batch)
    return FlexServeApp(registry, ensemble, engine)


def build_store_app(arch_names, store_dir: str, *, num_classes: int = 16,
                    max_len: int = 256, max_batch: int = 8,
                    full: bool = False, seed: int = 0) -> FlexServeApp:
    """Store-backed startup: seed the store on first run, then serve the
    LATEST published version of every member through a ModelManager."""
    store = ModelStore(store_dir)
    member_names = []
    for i, name in enumerate(arch_names):
        reg_name = f"{name}#{i}"
        member_names.append(reg_name)
        if store.latest_version(reg_name) is None:
            cfg = get_config(name)
            if not full:
                cfg = reduce_for_smoke(cfg)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(seed + i))
            v = store.publish(reg_name, params, config=name,
                              source=cfg.source,
                              meta={"reduced": not full,
                                    "num_classes": num_classes,
                                    "init_seed": seed + i})
            print(f"[serve] published {reg_name} v{v} to {store_dir}")
    manager = ModelManager(store, max_batch=max_batch)
    manager.bootstrap(member_names)
    engine = _build_engine(arch_names, max_len=max_len, max_batch=max_batch,
                           full=full, seed=seed)
    return FlexServeApp(engine=engine, manager=manager)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ensemble", nargs="+", default=["yi-9b"],
                    choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--num-classes", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--model-store", default=None, metavar="DIR",
                    help="versioned model store directory; enables the "
                         "lifecycle admin API and hot swaps")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    kw = dict(num_classes=args.num_classes, max_len=args.max_len,
              max_batch=args.max_batch, full=args.full)
    if args.model_store:
        app = build_store_app(args.ensemble, args.model_store, **kw)
    else:
        app = build_app(args.ensemble, **kw)
    server = FlexServeServer(app, host=args.host, port=args.port)
    host, port = server.address
    print(f"[serve] FlexServe endpoint on http://{host}:{port} — "
          f"{len(app.registry)} model(s): {app.registry.names()}")
    print("[serve] routes: GET /health /healthz /v1/models "
          "/v1/models/{name}; POST /v1/infer /v1/detect /v1/generate"
          + (" /v1/models/{name}/load|unload|rollback"
             if app.manager else ""))
    try:
        server.httpd.serve_forever()
    except KeyboardInterrupt:
        print("\n[serve] shutting down")
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
