"""Chunked RWKV-6 WKV recurrence — Pallas TPU kernel.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

Grid: (batch, heads, num_chunks); the chunk axis is sequential so the
(N, N) state lives in VMEM scratch across chunk steps.  All exponentials
take non-positive arguments (ordered-decay products), so the kernel is
stable regardless of how aggressive the learned data-dependent decay is —
no 1/W division anywhere.

Per-chunk working set (c=32, N=64): the (c,c,N) decay tensor is 256 KiB in
fp32, r/k/v/w tiles are 8 KiB each, state is 16 KiB — well inside VMEM.
The intra-chunk einsums contract on the MXU; chunk length trades VMEM
footprint against serialization (hillclimb knob).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import tpu_compiler_params


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sT_ref, s_s,
            *, chunk: int, nc: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        s_s[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)            # (c, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)          # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)               # (N,)
    S = s_s[...]                                   # (N, N) state

    c = r.shape[0]
    L = jnp.cumsum(lw, axis=0)                     # inclusive
    Lprev = L - lw                                 # exclusive
    # intra-chunk interactions: D[t,s,n] = exp(L_{t-1,n} - L_{s,n}), s < t
    D = jnp.exp(Lprev[:, None, :] - L[None, :, :])           # (c,c,N)
    A = jnp.einsum("tn,tsn,sn->ts", r, D, k)                 # (c,c)
    tril = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    A = jnp.where(tril, A, 0.0)
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # diagonal bonus
    y += jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    # contribution of the carried state
    y += jax.lax.dot_general(r * jnp.exp(Lprev), S,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    # state update: S' = diag(exp(L_c)) S + sum_s (k_s exp(L_c-L_s)) v_s^T
    Lc = L[-1:, :]                                  # (1, N)
    kd = k * jnp.exp(Lc - L)                        # (c, N)
    s_s[...] = jnp.exp(Lc)[0][:, None] * S + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == nc - 1)
    def _finish():
        sT_ref[0, 0, :, :] = s_s[...].astype(sT_ref.dtype)


def wkv6_bhtn(r, k, v, logw, u, s0, *, chunk: int = 32,
              interpret: bool = True):
    """r/k/v/logw (B,H,T,N) fp32; u (H,N); s0 (B,H,N,N).

    Returns (y (B,H,T,N), s_T (B,H,N,N)). T must divide by ``chunk``."""
    B, H, T, N = r.shape
    assert T % chunk == 0
    nc = T // chunk
    kern = functools.partial(_kernel, chunk=chunk, nc=nc)
    spec_t = pl.BlockSpec((1, 1, chunk, N), lambda b, h, j: (b, h, j, 0))
    spec_s = pl.BlockSpec((1, 1, N, N), lambda b, h, j: (b, h, 0, 0))
    y, sT = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[spec_t, spec_t, spec_t, spec_t,
                  pl.BlockSpec((1, N), lambda b, h, j: (h, 0)),
                  spec_s],
        out_specs=[spec_t, spec_s],
        out_shape=[jax.ShapeDtypeStruct((B, H, T, N), r.dtype),
                   jax.ShapeDtypeStruct((B, H, N, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="rwkv6_wkv",
    )(r, k, v, logw, u, s0)
    return y, sT
