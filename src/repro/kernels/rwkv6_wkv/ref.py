"""Oracle: direct sequential recurrence (independent of the chunked math)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u, s0):
    """r/k/v/logw (B,H,T,N); u (H,N); s0 (B,H,N,N) -> (y, s_T).

    Literal step-by-step recurrence:
        y_t = r_t (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    B, H, T, N = r.shape

    def step(S, xs):
        r_t, k_t, v_t, lw_t = xs                      # (B,H,N)
        bonus = u[None] * k_t                          # (B,H,N)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S) \
            + jnp.einsum("bhn,bhn->bh", r_t, bonus)[..., None] * v_t
        S = jnp.exp(lw_t)[..., None] * S + k_t[..., None] * v_t[..., None, :]
        return S, y

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (r, k, v, logw))  # (T,B,H,N)
    s_T, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 2), s_T
