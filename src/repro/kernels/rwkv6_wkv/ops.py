"""Public jit'd wrapper for the WKV-6 kernel (model layout (B,T,H,N))."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.rwkv6_wkv.kernel import wkv6_bhtn


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, s0, *, chunk: int = 32,
         interpret: Optional[bool] = None):
    """Model layout: r/k/v/logw (B,T,H,N); u (H,N); s0 (B,H,N,N).

    Returns (y (B,T,H,N), s_T). Pads T up to a chunk multiple with zero
    log-decay (= decay 1.0) and zero k/v, which leaves the state unchanged."""
    if interpret is None:
        interpret = default_interpret()
    B, T, H, N = r.shape
    Tp = -(-T // chunk) * chunk
    pad = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
    rt, kt, vt, wt = [jnp.moveaxis(jnp.pad(x, pad), 1, 2)
                      for x in (r, k, v, logw)]
    y, sT = wkv6_bhtn(rt.astype(jnp.float32), kt.astype(jnp.float32),
                      vt.astype(jnp.float32), wt.astype(jnp.float32),
                      u.astype(jnp.float32), s0.astype(jnp.float32),
                      chunk=chunk, interpret=interpret)
    return jnp.moveaxis(y, 2, 1)[:, :T], sT
