"""Public jit'd wrappers: cache layout (B,Smax,K,hd) -> kernel layout,
plus the paged entry point (page-pool layout (P,ps,K,hd) + page table).

``resolved_interpret`` is the single source of truth for which execution
mode a given ``interpret`` argument selects — benches report it so a run
on TPU provably measured the compiled kernel, not the interpreter.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, round_up
from repro.kernels.decode_attention.kernel import (
    decode_attention_bkgd, decode_attention_paged_bkgd)


def resolved_interpret(interpret: Optional[bool] = None) -> bool:
    """The execution mode an ``interpret`` override actually selects."""
    return default_interpret() if interpret is None else bool(interpret)


@functools.partial(jax.jit, static_argnames=("window", "kv_blk", "interpret"))
def decode_attention(q, cache_k, cache_v, lengths, *,
                     window: Optional[int] = None, kv_blk: int = 512,
                     interpret: Optional[bool] = None):
    """q (B,H,hd); cache_k/v (B,Smax,K,hd); lengths (B,) -> (B,H,hd)."""
    interpret = resolved_interpret(interpret)
    B, H, hd = q.shape
    Smax, K = cache_k.shape[1], cache_k.shape[2]
    G = H // K
    kv_blk = min(kv_blk, round_up(Smax, 8))
    Sp = round_up(Smax, kv_blk)
    qk = q.reshape(B, K, G, hd)
    kt = jnp.pad(jnp.moveaxis(cache_k, 2, 1),
                 ((0, 0), (0, 0), (0, Sp - Smax), (0, 0)))
    vt = jnp.pad(jnp.moveaxis(cache_v, 2, 1),
                 ((0, 0), (0, 0), (0, Sp - Smax), (0, 0)))
    out = decode_attention_bkgd(qk, kt, vt, lengths.astype(jnp.int32),
                                window=window, kv_blk=kv_blk,
                                interpret=interpret)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None):
    """Paged flash-decode: q (B,H,hd); k/v_pages (P,ps,K,hd) — the page
    pool in cache layout; page_table (B,MP) int32 mapping each row's
    logical pages to pool pages; lengths (B,) -> (B,H,hd).

    Equivalent to gathering each row's pages into a contiguous
    (B, MP*ps, K, hd) cache and running ``decode_attention`` — without
    ever materializing the gather."""
    interpret = resolved_interpret(interpret)
    B, H, hd = q.shape
    K = k_pages.shape[2]
    G = H // K
    qk = q.reshape(B, K, G, hd)
    kt = jnp.moveaxis(k_pages, 2, 1)                   # (P, K, ps, hd)
    vt = jnp.moveaxis(v_pages, 2, 1)
    out = decode_attention_paged_bkgd(
        qk, kt, vt, page_table.astype(jnp.int32),
        lengths.astype(jnp.int32), window=window, interpret=interpret)
    return out.reshape(B, H, hd)
