"""Public jit'd wrapper: cache layout (B,Smax,K,hd) -> kernel layout."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, round_up
from repro.kernels.decode_attention.kernel import decode_attention_bkgd


@functools.partial(jax.jit, static_argnames=("window", "kv_blk", "interpret"))
def decode_attention(q, cache_k, cache_v, lengths, *,
                     window: Optional[int] = None, kv_blk: int = 512,
                     interpret: Optional[bool] = None):
    """q (B,H,hd); cache_k/v (B,Smax,K,hd); lengths (B,) -> (B,H,hd)."""
    if interpret is None:
        interpret = default_interpret()
    B, H, hd = q.shape
    Smax, K = cache_k.shape[1], cache_k.shape[2]
    G = H // K
    kv_blk = min(kv_blk, round_up(Smax, 8))
    Sp = round_up(Smax, kv_blk)
    qk = q.reshape(B, K, G, hd)
    kt = jnp.pad(jnp.moveaxis(cache_k, 2, 1),
                 ((0, 0), (0, 0), (0, Sp - Smax), (0, 0)))
    vt = jnp.pad(jnp.moveaxis(cache_v, 2, 1),
                 ((0, 0), (0, 0), (0, Sp - Smax), (0, 0)))
    out = decode_attention_bkgd(qk, kt, vt, lengths.astype(jnp.int32),
                                window=window, kv_blk=kv_blk,
                                interpret=interpret)
    return out.reshape(B, H, hd)
