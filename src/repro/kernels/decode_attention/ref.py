"""Oracle: one-token attention vs cache (reuses the model-layer reference,
which is an independent einsum implementation)."""

from __future__ import annotations

from typing import Optional

from repro.models.attention import decode_attention_ref


def decode_attention_oracle(q, cache_k, cache_v, lengths, *,
                            window: Optional[int] = None):
    """q (B,H,hd); cache_k/v (B,Smax,K,hd); lengths (B,) -> (B,H,hd)."""
    return decode_attention_ref(q, cache_k, cache_v, lengths, window=window)


def paged_decode_attention_oracle(q, k_pages, v_pages, page_table,
                                  lengths, *, window: Optional[int] = None):
    """Paged oracle: gather each row's pages into the contiguous cache it
    stands for, then run the contiguous reference.  q (B,H,hd);
    k/v_pages (P,ps,K,hd); page_table (B,MP); lengths (B,) -> (B,H,hd)."""
    B, MP = page_table.shape
    _, ps, K, hd = k_pages.shape
    ck = k_pages[page_table].reshape(B, MP * ps, K, hd)
    cv = v_pages[page_table].reshape(B, MP * ps, K, hd)
    return decode_attention_ref(q, ck, cv, lengths, window=window)
