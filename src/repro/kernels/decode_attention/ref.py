"""Oracle: one-token attention vs cache (reuses the model-layer reference,
which is an independent einsum implementation)."""

from __future__ import annotations

from typing import Optional

from repro.models.attention import decode_attention_ref


def decode_attention_oracle(q, cache_k, cache_v, lengths, *,
                            window: Optional[int] = None):
    """q (B,H,hd); cache_k/v (B,Smax,K,hd); lengths (B,) -> (B,H,hd)."""
    return decode_attention_ref(q, cache_k, cache_v, lengths, window=window)
