from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention,
                                                resolved_interpret)
from repro.kernels.decode_attention.ref import (decode_attention_oracle,
                                                paged_decode_attention_oracle)

__all__ = ["decode_attention", "decode_attention_oracle",
           "paged_decode_attention", "paged_decode_attention_oracle",
           "resolved_interpret"]
