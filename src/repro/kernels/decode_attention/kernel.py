"""Flash-decode: one-token GQA attention against a long KV cache.

The decode_32k / long_500k hot spot.  All G query heads sharing a kv head
are processed together, so the inner matmul is (G, hd) x (hd, kv_blk) —
for GQA ratios 4..8 this keeps the MXU fed while each kv tile is streamed
through VMEM exactly once.

Grid: (batch, kv_heads, num_kv_blocks), kv innermost/sequential with the
online-softmax running stats in VMEM scratch.  Per-row valid ``lengths``
live in SMEM (scalar-like), giving the ragged masking continuous batching
needs; sliding-window serving masks kv below (length - window).

VMEM working set with kv_blk=512, hd=128, G<=8:
2 * 512*128 (k,v tile) * 4B + G*128 acc ≈ 0.5 MiB.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (NEG_INF, default_interpret,
                                  tpu_compiler_params)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            window: Optional[int], kv_blk: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    length = len_ref[0]                                   # this row's #valid
    q = q_ref[0, 0].astype(jnp.float32)                   # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (kv_blk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    hd = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / (hd ** 0.5))                           # (G, kv_blk)

    kpos = j * kv_blk + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    mask = kpos < length
    if window is not None:
        mask &= kpos > length - 1 - window
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_s[...]
    m_new = jnp.maximum(m_old, s.max(axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_old - m_new)
    l_s[...] = alpha * l_s[...] + p.sum(axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_s[...] / l).astype(o_ref.dtype)


def decode_attention_bkgd(q, k, v, lengths, *, window: Optional[int] = None,
                          kv_blk: int = 512,
                          interpret: Optional[bool] = None):
    """q (B,K,G,hd); k/v (B,K,Smax,hd); lengths (B,) int32 -> (B,K,G,hd).

    ``interpret=None`` selects by backend: compiled on TPU, interpreter
    everywhere else (it used to hardcode True, silently interpreting on
    real TPUs); pass an explicit bool to override."""
    if interpret is None:
        interpret = default_interpret()
    B, K, G, hd = q.shape
    Smax = k.shape[2]
    assert Smax % kv_blk == 0
    nk = Smax // kv_blk
    kern = functools.partial(_kernel, window=window, kv_blk=kv_blk, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(B, K, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, kv_blk, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, kv_blk, hd), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="decode_attention",
    )(lengths, q, k, v)


# ---------------------------------------------------------------------------
# Paged variant: page-table indirection into a shared KV page pool
# ---------------------------------------------------------------------------


def _paged_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s,
                  acc_s, *, window: Optional[int], page_size: int, np_: int):
    """Same online-softmax loop as ``_kernel``, but the kv tile for grid
    step j is row b's j-th LOGICAL page, DMA'd from physical page
    ``pt_ref[b, j]`` of the pool (the BlockSpec index_map reads the
    scalar-prefetched page table).  ``lengths`` and the page table live in
    SMEM; the VMEM working set is one (page_size, hd) k/v tile — identical
    to the contiguous kernel with kv_blk=page_size.  Pages past a row's
    length alias the dump page and are masked off by ``kpos < length``."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    length = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                   # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (page_size, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    hd = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / (hd ** 0.5))                           # (G, page_size)

    kpos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < length
    if window is not None:
        mask &= kpos > length - 1 - window
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_s[...]
    m_new = jnp.maximum(m_old, s.max(axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_old - m_new)
    l_s[...] = alpha * l_s[...] + p.sum(axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == np_ - 1)
    def _finish():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_s[...] / l).astype(o_ref.dtype)


def decode_attention_paged_bkgd(q, k_pages, v_pages, page_table, lengths, *,
                                window: Optional[int] = None,
                                interpret: Optional[bool] = None):
    """q (B,K,G,hd); k/v_pages (P,K,page_size,hd); page_table (B,MP) int32;
    lengths (B,) int32 -> (B,K,G,hd).

    Grid (B, K, MP) with the kv-page axis sequential; ``lengths`` and
    ``page_table`` ride in as scalar-prefetch operands so the k/v
    BlockSpec index_maps can turn logical page j into the physical pool
    page before the tile DMA issues."""
    if interpret is None:
        interpret = default_interpret()
    B, K, G, hd = q.shape
    page_size = k_pages.shape[2]
    MP = page_table.shape[1]
    kern = functools.partial(_paged_kernel, window=window,
                             page_size=page_size, np_=MP)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, MP),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, j, len_ref, pt_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd),
                         lambda b, h, j, len_ref, pt_ref:
                         (pt_ref[b, j], h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd),
                         lambda b, h, j, len_ref, pt_ref:
                         (pt_ref[b, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, h, j, len_ref, pt_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="decode_attention_paged",
    )(lengths, page_table, q, k_pages, v_pages)
