"""Shared kernel utilities."""

from __future__ import annotations

import functools

import jax

NEG_INF = -1e30


@functools.cache
def default_interpret() -> bool:
    """Interpret Pallas kernels unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
