"""Shared kernel utilities."""

from __future__ import annotations

import functools

import jax
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


@functools.cache
def default_interpret() -> bool:
    """Interpret Pallas kernels unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def tpu_compiler_params(**kw):
    """``pltpu.TPUCompilerParams`` was renamed ``CompilerParams`` across
    jax releases; resolve whichever this install provides."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kw)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
