"""Public jit'd wrapper: model layout (B,S,H,hd) -> kernel layout, padding."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret, round_up
from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_blk",
                                             "kv_blk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, lengths=None,
                    q_blk: int = 128,
                    kv_blk: int = 128, interpret: Optional[bool] = None):
    """Flash attention in model layout: q (B,S,H,hd), k/v (B,S,K,hd).

    Pads S up to the block size; padded keys are masked inside the kernel.
    ``lengths`` (B,) enables ragged right-padded prefill batches.
    Returns (B,S,H,hd) in q.dtype."""
    if interpret is None:
        interpret = default_interpret()
    B, S, H, hd = q.shape
    K = k.shape[2]
    q_blk = min(q_blk, round_up(S, 8))
    kv_blk = min(kv_blk, round_up(S, 8))
    Sq = round_up(S, q_blk)
    Skv = round_up(S, kv_blk)
    qt = jnp.moveaxis(q, 2, 1)                    # (B,H,S,hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sq - S), (0, 0)))
    kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skv - S), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skv - S), (0, 0)))
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               seq_len=S, lengths=lengths,
                               q_blk=q_blk, kv_blk=kv_blk,
                               interpret=interpret)
    return jnp.moveaxis(out[:, :, :S], 1, 2)
