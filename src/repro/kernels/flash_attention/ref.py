"""Pure-jnp oracle for flash attention (materializes the full score matrix)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        seq_len: Optional[int] = None, lengths=None):
    """q (B,H,Sq,hd); k/v (B,K,Skv,hd). Naive masked softmax attention."""
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    seq_len = Skv if seq_len is None else seq_len
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / (hd ** 0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = kpos < seq_len
    mask = jnp.broadcast_to(mask, (Sq, Skv))[None]
    if lengths is not None:
        mask = mask & (kpos[None] < lengths[:, None, None])
    if causal:
        mask = mask & (kpos <= qpos)[None]
    if window is not None:
        mask = mask & (kpos > qpos - window)[None]
    mask = mask[:, None]                        # (B|1, 1, Sq, Skv)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)   # rows with no valid key -> all zeros
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
