"""Blocked flash attention (prefill hot spot) — Pallas TPU kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks), kv innermost and
sequential ("arbitrary") so the online-softmax running statistics can live
in VMEM scratch across kv steps.  GQA is handled in the k/v index_map
(q-head h reads kv-head h // group_size).

BlockSpec tiling: q/o tiles (q_blk, head_dim), k/v tiles (kv_blk, head_dim),
VMEM scratch m/l (q_blk, 1) and acc (q_blk, head_dim) in fp32.  With the
default q_blk = kv_blk = 128 and head_dim 64..128, the working set is
~(2*128*128 + 128*128)*4B ≈ 200 KiB — comfortably inside the ~16 MiB VMEM
per core, and all matmul dims are MXU-aligned (multiples of 128 where the
dtype requires it).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, cdiv, tpu_compiler_params


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            scale: float, causal: bool, window: Optional[int],
            q_blk: int, kv_blk: int, nk: int):
    b = pl.program_id(0)          # batch row
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block
    seq_len = len_ref[0]          # this row's valid kv length (ragged)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)                    # (q_blk, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (kv_blk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = i * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
    kpos = j * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
    mask = kpos < seq_len                                  # pad keys
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_s[...]                                       # (q_blk, 1)
    m_new = jnp.maximum(m_old, s.max(axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_old - m_new)
    l_s[...] = alpha * l_s[...] + p.sum(axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_s[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: Optional[int] = None,
                         seq_len: Optional[int] = None,
                         lengths=None,
                         q_blk: int = 128, kv_blk: int = 128,
                         interpret: bool = True):
    """q (B,H,Sq,hd); k/v (B,K,Skv,hd), H % K == 0. Sq/Skv already padded
    to block multiples; ``seq_len`` = number of valid kv positions, or
    ``lengths`` (B,) int32 for per-row ragged prefill."""
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    assert Sq % q_blk == 0 and Skv % kv_blk == 0
    nq, nk = Sq // q_blk, Skv // kv_blk
    seq_len = Skv if seq_len is None else seq_len
    scale = 1.0 / (hd ** 0.5)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_blk=q_blk, kv_blk=kv_blk, nk=nk)

    if lengths is None:
        lengths = jnp.full((B,), seq_len, jnp.int32)
    return pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, q_blk, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kv_blk, hd),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, kv_blk, hd),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(lengths.astype(jnp.int32), q, k, v)
