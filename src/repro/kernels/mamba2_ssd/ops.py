"""Public jit'd wrapper for the SSD kernel (model layout (B,T,H,P))."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.mamba2_ssd.kernel import ssd_bhtp


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, h0, *, chunk: int = 128,
        interpret: Optional[bool] = None):
    """Model layout: x (B,T,H,P); dt (B,T,H); A (H,)<0; Bm/Cm (B,T,N);
    h0 (B,H,P,N).  Returns (y (B,T,H,P), h_T).

    Pads T to a chunk multiple with dt=0 (decay=1, no state change)."""
    if interpret is None:
        interpret = default_interpret()
    B, T, H, P = x.shape
    Tp = -(-T // chunk) * chunk
    pad3 = ((0, 0), (0, Tp - T), (0, 0))
    xt = jnp.moveaxis(jnp.pad(x, pad3 + ((0, 0),)), 1, 2)
    dtt = jnp.moveaxis(jnp.pad(dt, pad3), 1, 2)[..., None]     # (B,H,Tp,1)
    dAt = dtt * A[None, :, None, None]
    Bp = jnp.pad(Bm, pad3)
    Cp = jnp.pad(Cm, pad3)
    y, hT = ssd_bhtp(xt.astype(jnp.float32), dtt.astype(jnp.float32),
                     dAt.astype(jnp.float32), Bp.astype(jnp.float32),
                     Cp.astype(jnp.float32), h0.astype(jnp.float32),
                     chunk=chunk, interpret=interpret)
    return jnp.moveaxis(y, 2, 1)[:, :T], hT
