from repro.kernels.mamba2_ssd.ops import ssd
from repro.kernels.mamba2_ssd.ref import ssd_ref

__all__ = ["ssd", "ssd_ref"]
