"""Chunked Mamba-2 SSD scan — Pallas TPU kernel (zamba2 backbone hot spot).

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T ;  y_t = h_t C_t + (skip)

Grid: (batch, heads, num_chunks), chunk axis sequential; the (P, N) head
state lives in VMEM scratch across chunks.  The intra-chunk term uses the
SSD quadratic form with a log-space segment-sum decay matrix; decays are
scalar per (head, step) so the (c, c) decay matrix costs c^2 fp32 — tiny.

Per-chunk working set (c=128, P=64, N=64): x tile 32 KiB, B/C tiles
32 KiB each, (c,c) decay 64 KiB, state 16 KiB — VMEM-friendly, and the
three einsums ((c,c)x(c,P), (c,N)x(N,P), (c,P)x(c,N)) are MXU shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import tpu_compiler_params


def _kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, h0_ref, y_ref, hT_ref, h_s,
            *, chunk: int, nc: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_s[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)              # (c, P)
    dt = dt_ref[0, 0].astype(jnp.float32)            # (c, 1)
    dA = da_ref[0, 0].astype(jnp.float32)            # (c, 1) log decay <= 0
    Bm = b_ref[0].astype(jnp.float32)                # (c, N)
    Cm = c_ref[0].astype(jnp.float32)                # (c, N)
    h = h_s[...]                                     # (P, N)

    c = x.shape[0]
    L = jnp.cumsum(dA[:, 0])                         # (c,) inclusive
    # segment-sum decay matrix: M[t,s] = exp(L_t - L_s), s <= t else 0
    diff = L[:, None] - L[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    M = jnp.where(tril, jnp.exp(jnp.where(tril, diff, 0.0)), 0.0)
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (c,c)
    W = M * G                                        # (c,c)
    xdt = x * dt                                     # (c, P)
    y = jax.lax.dot_general(W, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (c,P)
    # inter-chunk state contribution: y_t += exp(L_t) C_t h^T
    Ch = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c,P)
    y += jnp.exp(L)[:, None] * Ch
    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    # state update: h' = exp(L_c) h + sum_s exp(L_c - L_s) dt_s x_s B_s^T
    Lc = L[-1]
    wdecay = jnp.exp(Lc - L)[:, None]                # (c,1)
    upd = jax.lax.dot_general(xdt * wdecay, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P,N)
    h_s[...] = jnp.exp(Lc) * h + upd

    @pl.when(j == nc - 1)
    def _finish():
        hT_ref[0, 0, :, :] = h_s[...].astype(hT_ref.dtype)


def ssd_bhtp(x, dt, dA, Bm, Cm, h0, *, chunk: int = 128,
             interpret: bool = True):
    """x (B,H,T,P); dt/dA (B,H,T,1); Bm/Cm (B,T,N); h0 (B,H,P,N).

    Returns (y (B,H,T,P), h_T (B,H,P,N)). T must divide by ``chunk``."""
    B, H, T, P = x.shape
    N = Bm.shape[-1]
    assert T % chunk == 0
    nc = T // chunk
    kern = functools.partial(_kernel, chunk=chunk, nc=nc)
    y, hT = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, H, T, P), x.dtype),
                   jax.ShapeDtypeStruct((B, H, P, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="mamba2_ssd",
    )(x, dt, dA, Bm, Cm, h0)
    return y, hT
