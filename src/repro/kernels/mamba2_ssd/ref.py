"""Oracle: literal sequential SSD recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm, h0):
    """x (B,T,H,P); dt (B,T,H); A (H,); Bm/Cm (B,T,N); h0 (B,H,P,N).

    y_t = C_t . h_t where h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T."""
    def step(h, xs):
        x_t, dt_t, B_t, C_t = xs                     # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dt_t * A)                    # (B,H)
        h = decay[..., None, None] * h + jnp.einsum(
            "bh,bhp,bn->bhpn", dt_t, x_t, B_t)
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h_T, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), h_T
