"""Pallas TPU kernels for the serving hot spots.

Each kernel package ships three modules:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (layout handling, interpret switch)
  ref.py    — pure-jnp oracle, written INDEPENDENTLY of the kernel math

On this CPU container kernels are validated with interpret=True; on TPU the
same code compiles natively.  ``interpret`` defaults to True when no TPU is
present (see repro.kernels.common.default_interpret).
"""

from repro.kernels import common

__all__ = ["common"]
