from repro.training import checkpoint, data, optimizer
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import OptimizerConfig, OptState
from repro.training.train_loop import Trainer, TrainerConfig, make_train_step

__all__ = [
    "checkpoint", "data", "optimizer", "DataConfig", "SyntheticLM",
    "OptimizerConfig", "OptState", "Trainer", "TrainerConfig",
    "make_train_step",
]
