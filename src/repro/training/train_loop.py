"""pjit training loop with gradient accumulation and checkpointing."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.build import Model
from repro.training import checkpoint, optimizer
from repro.training.optimizer import OptimizerConfig, OptState


def make_train_step(model: Model, opt_cfg: OptimizerConfig,
                    grad_accum: int = 1, remat: bool = True,
                    accum_dtype=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With grad_accum > 1 the global batch is split into microbatches scanned
    sequentially (activation memory / batch trade-off — a §Perf knob)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accum_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def micro(carry, mb):
            acc, lsum = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(a.dtype), acc, grads)
            return (acc, lsum + loss), None

        split = jax.tree_util.tree_map(
            lambda t: t.reshape(grad_accum, t.shape[0] // grad_accum,
                                *t.shape[1:]), batch)
        adt = accum_dtype or jnp.float32
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, adt), params)
        (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), split)
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        loss = lsum / grad_accum
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state: OptState, batch):
        loss, metrics, grads = accum_grads(params, batch)
        params, opt_state, opt_metrics = optimizer.update(
            grads, opt_state, params, opt_cfg)
        return params, opt_state, {**metrics, **opt_metrics, "loss": loss}

    return train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0                  # 0 = only final
    ckpt_dir: Optional[str] = None
    grad_accum: int = 1
    remat: bool = True


class Trainer:
    def __init__(self, model: Model, opt_cfg: OptimizerConfig,
                 tcfg: TrainerConfig, params=None, rng=None):
        self.model = model
        self.tcfg = tcfg
        self.params = params if params is not None else model.init(
            rng if rng is not None else jax.random.PRNGKey(0))
        self.opt_state = optimizer.init(self.params, opt_cfg.moment_dtype)
        self._step_fn = jax.jit(make_train_step(
            model, opt_cfg, grad_accum=tcfg.grad_accum, remat=tcfg.remat),
            donate_argnums=(0, 1))
        self.history: List[Dict[str, float]] = []

    def fit(self, data_iter, steps: Optional[int] = None,
            log: Callable[[str], None] = print) -> List[Dict[str, float]]:
        steps = steps or self.tcfg.total_steps
        t0 = time.perf_counter()
        for step in range(1, steps + 1):
            batch = next(data_iter)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            if step % self.tcfg.log_every == 0 or step == steps:
                row = {k: float(v) for k, v in metrics.items()}
                row["step"] = step
                row["wall_s"] = time.perf_counter() - t0
                self.history.append(row)
                log(f"step {step:5d}  loss {row['loss']:.4f}  "
                    f"lr {row.get('lr', 0):.2e}  "
                    f"gnorm {row.get('grad_norm', 0):.2f}  "
                    f"{row['wall_s']:.1f}s")
            if (self.tcfg.ckpt_every and self.tcfg.ckpt_dir
                    and step % self.tcfg.ckpt_every == 0):
                self.save(step)
        if self.tcfg.ckpt_dir:
            self.save(steps)
        return self.history

    def save(self, step: int) -> str:
        path = f"{self.tcfg.ckpt_dir}/step_{step}.ckpt"
        return checkpoint.save(path, {"params": self.params}, step=step,
                               meta={"arch": self.model.config.name})

    def restore(self, path: str) -> None:
        tree, _ = checkpoint.restore(path, {"params": self.params})
        self.params = tree["params"]
