"""AdamW + LR schedules in pure JAX (no optax in this container)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"          # cosine | linear | constant
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Optional[str] = None   # 'bfloat16' = DeepSeek-V3 recipe


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def lr_at(step, cfg: OptimizerConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.ones_like(frac)
    return cfg.peak_lr * warm * decay


def init(params, moment_dtype: Optional[str] = None) -> OptState:
    dt = jnp.bfloat16 if moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dt), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def _decayable(path) -> bool:
    """No weight decay on norms/biases/1D params (standard practice)."""
    name = ""
    for part in reversed(path):
        key = getattr(part, "key", None)
        if isinstance(key, str):
            name = key
            break
    return not any(s in name for s in ("scale", "bias", "nbias", "norm",
                                       "mu", "w0", "first", "a_log",
                                       "dt_bias", "d_skip", "gate"))


def update(grads, state: OptState, params,
           cfg: OptimizerConfig) -> Tuple[Any, OptState, Dict[str, Any]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(step, cfg)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    paths = [p for p, _ in
             jax.tree_util.tree_flatten_with_path(grads)[0]]

    def one(g, m, n, p, path):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        n2 = cfg.b2 * n.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        upd = (m2 / bc1) / (jnp.sqrt(n2 / bc2) + cfg.eps)
        if _decayable(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * upd
        return p2.astype(p.dtype), m2.astype(m.dtype), n2.astype(n.dtype)

    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_n = jax.tree_util.tree_leaves(state.nu)
    flat_p = jax.tree_util.tree_leaves(params)
    out = [one(g, m, n, p, path) for g, m, n, p, path in
           zip(flat_g, flat_m, flat_n, flat_p, paths)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_n = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, OptState(step=step, mu=new_m, nu=new_n), metrics
