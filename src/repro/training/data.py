"""Deterministic synthetic LM data pipeline.

Sequences follow a learnable second-order pattern with noise:
    t_{i+1} = (a * t_i + b * t_{i-1} + c) mod V          (prob 1-noise)
             ~ Uniform(V)                                 (prob noise)
with (a, b, c) drawn per-sequence from a small set of "dialects", so a
model must infer the dialect in-context — losses drop quickly but not to
zero, giving training curves with signal at smoke scale.

The pipeline is an infinite, seekable iterator (step -> batch) so
checkpoint-resume reproduces the exact stream.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    noise: float = 0.05
    num_dialects: int = 8
    seed: int = 0


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        self.dialects = rng.integers(
            1, V, size=(cfg.num_dialects, 3))         # (a, b, c)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        d = rng.integers(0, cfg.num_dialects, size=B)
        a, b, c = (self.dialects[d, i][:, None] for i in range(3))
        seq = np.empty((B, S + 1), np.int64)
        seq[:, 0] = rng.integers(0, V, size=B)
        seq[:, 1] = rng.integers(0, V, size=B)
        for i in range(1, S):
            nxt = (a[:, 0] * seq[:, i] + b[:, 0] * seq[:, i - 1]
                   + c[:, 0]) % V
            noise = rng.random(B) < cfg.noise
            seq[:, i + 1] = np.where(noise, rng.integers(0, V, size=B), nxt)
        return {"tokens": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
