"""Checkpointing: msgpack (+ optional zstd) of a flattened pytree.

Layout: <dir>/step_<n>.ckpt — a msgpack map
{"meta": {...}, "leaves": {"/path/to/leaf": {dtype, shape, data}}},
zstd-compressed when the ``zstandard`` package is present, raw otherwise
(the loader sniffs the zstd frame magic, so both layouts interoperate).
Trees are restored onto the host then device_put by the caller (so the
restore path composes with any sharding).
"""

from __future__ import annotations

import hashlib
import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                                    # optional dependency
    import zstandard
except ImportError:                     # pragma: no cover - env dependent
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree, *, step: int = 0,
         meta: Optional[Dict[str, Any]] = None) -> str:
    flat = _flatten(tree)
    payload = {
        "meta": dict(meta or {}, step=step),
        "leaves": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in flat.items()
        },
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    raw = msgpack.packb(payload, use_bin_type=True)
    if zstandard is not None:
        raw = zstandard.ZstdCompressor(level=3).compress(raw)
    with open(path, "wb") as f:
        f.write(raw)
    return path


def load(path: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                f"{path} is zstd-compressed but the 'zstandard' package is "
                "not installed; install it or re-save uncompressed")
        raw = zstandard.ZstdDecompressor().decompress(raw)
    payload = msgpack.unpackb(raw, raw=False)
    leaves = {
        k: np.frombuffer(v["data"],
                         dtype=np.dtype(v["dtype"])).reshape(v["shape"])
        for k, v in payload["leaves"].items()
    }
    return leaves, payload["meta"]


def restore(path: str, like_tree) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    leaves, meta = load(path)
    flat_like = jax.tree_util.tree_flatten_with_path(like_tree)
    restored = []
    for p, leaf in flat_like[0]:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = leaves[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        restored.append(jnp.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(flat_like[1], restored)
    return tree, meta


def param_hash(tree) -> str:
    """Content hash of a pytree's leaves (order-independent provenance id).

    Hashes every leaf's path, dtype, shape, and raw bytes under a stable
    (sorted-path) order, so the same params always produce the same digest
    regardless of container insertion order or host.
    """
    h = hashlib.sha256()
    flat = _flatten(tree)
    for key in sorted(flat):
        v = flat[key]
        h.update(key.encode())
        h.update(str(v.dtype).encode())
        h.update(str(tuple(v.shape)).encode())
        h.update(np.ascontiguousarray(v).tobytes())
    return h.hexdigest()


def write_manifest(path: str, manifest: Dict[str, Any]) -> str:
    """Atomically write a provenance manifest (JSON) next to a checkpoint.

    Write-then-rename so a reader never observes a torn manifest — admin
    threads read manifests while loads are in progress.
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return path


def read_manifest(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def latest(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    best, best_step = None, -1
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)\.ckpt", name)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(ckpt_dir, name), int(m.group(1))
    return best
