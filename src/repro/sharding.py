"""Logical-axis sharding rules.

Models annotate activations with *logical* axes ("batch", "seq", "heads",
"ff", "embed", "vocab", "expert", "kv") and parameters are assigned specs by
leaf name.  The translation to mesh axes adapts to whichever production mesh
is active:

  single-pod mesh (data=16, model=16):   batch->data, heads/ff/vocab->model
  multi-pod mesh (pod=2, data=16, model=16): batch->(pod,data), rest as above

The 2D weight sharding (d_model dim -> data, ff/head dim -> model) is
HSDP-style: tensor parallelism over ``model`` with FSDP-style weight
sharding over ``data`` so that >100B-param archs fit 16 GB/chip HBM.

No jax device state is touched at import time.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Active-mesh context
# ---------------------------------------------------------------------------

_state = threading.local()


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Install ``mesh`` as the active mesh for logical-axis translation.

    Also enters the jax mesh context so ``with_sharding_constraint`` works.
    """
    prev = get_mesh()
    _state.mesh = mesh
    try:
        if mesh is None:
            yield
        else:
            with mesh:
                yield
    finally:
        _state.mesh = prev


# ---------------------------------------------------------------------------
# Logical -> physical translation
# ---------------------------------------------------------------------------

# logical axis -> preferred mesh axis (by name)
_LOGICAL = {
    "batch": ("data",),
    "expert": ("data",),       # expert parallelism rides the data axis
    "heads": ("model",),
    "kv": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "embed": ("data",),        # FSDP axis for the d_model dim of weights
    "seq": (),                 # unsharded by default (overridden for 500k KV)
    "seq_sp": (),              # residual-stream seq dim; ("model",) under
                               # the seq_parallel optimization (see below)
    "seq_shard": ("model",),   # KV seq sharded over model (decode, kv<16)
    "seq_full": ("data", "model"),  # KV seq sharded over ALL chips (batch=1)
    None: (),
}


def physical_axes(logical: Optional[str], mesh: Mesh):
    """Mesh axes for one logical axis, given the active mesh's axis names."""
    from repro import opt
    if logical is None:
        return None
    if logical == "seq_sp":
        return ("model" if (opt.enabled("seq_parallel")
                            and "model" in mesh.axis_names) else None)
    if logical == "embed" and opt.enabled("serve_tp"):
        # serving TP: the d_model dim of weights shards over `pod` (when
        # present) instead of `data`, so decode never re-gathers weights
        # across the data axis; batch stays on `data`.
        return "pod" if "pod" in mesh.axis_names else None
    want = _LOGICAL[logical]
    have = mesh.axis_names
    out = []
    for ax in want:
        if ax in have:
            out.append(ax)
        # pod extends the data axis (training batch / serving replicas)
        if ax == "data" and "pod" in have:
            out.insert(0, "pod")
    if not out:
        return None
    return tuple(out) if len(out) > 1 else out[0]


def logical_to_spec(*logical_axes, mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or get_mesh()
    if mesh is None:
        return P()
    return P(*[physical_axes(a, mesh) for a in logical_axes])


def shard(x, *logical_axes):
    """Constrain an activation's sharding by logical axes. No-op w/o a mesh."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(*logical_axes, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs by leaf name
# ---------------------------------------------------------------------------

# Leaf-name -> logical axes of the *trailing* dims (layer-stack dims handled
# by rank padding below).  Names match the init functions in repro.models.
_PARAM_RULES = {
    # embeddings / head
    "embed": ("vocab", "embed"),
    "head": ("embed", "vocab"),
    "pos_embed": (None, None),
    # attention
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv"),
    "wv": ("embed", "kv"),
    "wo": ("heads", "embed"),
    "bq": ("heads",), "bk": ("kv",), "bv": ("kv",), "bo": (None,),
    # MLA
    "q_a": ("embed", None),
    "q_b": (None, "heads"),
    "kv_a": ("embed", None),
    "kv_b": (None, "heads"),
    # mlp
    "w_gate": ("embed", "ff"),
    "w_up": ("embed", "ff"),
    "w_down": ("ff", "embed"),
    "b_gate": ("ff",), "b_up": ("ff",), "b_down": (None,),
    # MoE (leading expert dim)
    "we_gate": ("expert", None, "ff"),
    "we_up": ("expert", None, "ff"),
    "we_down": ("expert", "ff", None),
    "router": ("embed", None),
    # shared expert uses plain mlp names via ws_* aliases
    "ws_gate": ("embed", "ff"),
    "ws_up": ("embed", "ff"),
    "ws_down": ("ff", "embed"),
    # rwkv6 square mixes
    "w_r": ("embed", "heads"), "w_k": ("embed", "heads"),
    "w_v": ("embed", "heads"), "w_g": ("embed", "heads"),
    "w_o": ("heads", "embed"),
    # mamba2
    "in_proj": ("embed", "ff"),
    "out_proj": ("ff", "embed"),
    "conv_w": (None, "ff"),
    "conv_b": ("ff",),
    # vlm / zamba2 adapters
    "img_k": ("embed", "kv"), "img_v": ("embed", "kv"),
    "concat_proj": (None, "embed"),
    "lora_a": ("embed", None), "lora_b": (None, "heads"),
}

_REPLICATED_SUFFIXES = (
    "scale", "bias", "mu", "decay", "first", "gate_scalar", "dt_bias",
    "a_log", "d_skip", "norm", "qnorm", "knorm",
)


def spec_for_leaf(path: tuple, leaf) -> P:
    """PartitionSpec for one param leaf, from its name + rank."""
    name = None
    for part in reversed(path):
        key = getattr(part, "key", None) or getattr(part, "name", None)
        if isinstance(key, str):
            name = key
            break
    rank = len(leaf.shape)
    if name is None:
        return P()
    base = _PARAM_RULES.get(name)
    if base is None:
        for suf in _REPLICATED_SUFFIXES:
            if name.endswith(suf) or name.startswith(suf):
                return P(*([None] * rank))
        # unknown: replicate (safe default)
        return P(*([None] * rank))
    # pad leading layer-stack dims with None
    pad = rank - len(base)
    if pad < 0:  # leaf smaller than rule (e.g. smoke config folded dims)
        base = base[-rank:]
        pad = 0
    return P(*([None] * pad), *base)


def param_specs(params_tree, mesh: Optional[Mesh] = None):
    """Pytree of PartitionSpec translated for ``mesh`` (or active mesh)."""
    mesh = mesh or get_mesh()

    def one(path, leaf):
        logical = spec_for_leaf(path, leaf)
        if mesh is None:
            return P()
        return P(*[physical_axes(a, mesh) if isinstance(a, str) else None
                   for a in logical])

    return jax.tree_util.tree_map_with_path(one, params_tree)


def param_shardings(params_tree, mesh: Optional[Mesh] = None):
    mesh = mesh or get_mesh()
    if mesh is None:
        raise ValueError("param_shardings requires an active mesh")
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params_tree, mesh))
